"""End-to-end behaviour tests for the full RL system (smoke scale)."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RLConfig
from repro.core.trainer import GRPOTrainer
from repro.data.prompts import PromptDataset, pattern_task

TINY = ModelConfig(
    name="tiny", arch_type="dense", num_layers=2, d_model=128,
    vocab_size=512, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
    dtype="float32", remat=False)


def _trainer(**flags):
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=8,
                  lr=1e-4, **flags)
    ds = PromptDataset(pattern_task(), max_prompt_len=12, seed=0)
    return GRPOTrainer(TINY, rl, ds, num_nodes=4, seed=0)


def test_iteration_runs_and_metrics_finite():
    tr = _trainer()
    st = tr.iteration(global_batch=4)
    assert np.isfinite(st.loss) and np.isfinite(st.kl)
    assert 0.0 <= st.reward_mean <= 1.0
    assert st.dispatch["requests"] > 0
    assert st.reshard["d2h_bytes"] > 0          # allgather-swap engaged
    # every sample consumed exactly once by the update state
    assert len(tr.dock.controllers["actor_update"].consumed) == 8


def test_params_update_and_ref_frozen():
    # entropy bonus gives the objective a gradient even when the untrained
    # policy earns zero reward everywhere (whether a random rollout hits the
    # pattern task is platform/seed luck — zero advantages give a genuinely
    # zero policy gradient, which is correct but would make this test flaky)
    tr = _trainer(entropy_coef=0.01)
    ref_before = jax.tree.map(lambda x: np.asarray(x).copy(),
                              tr.ref_params)
    tr.iteration(global_batch=4)
    # reference stayed identical
    for a, b in zip(jax.tree.leaves(ref_before),
                    jax.tree.leaves(tr.ref_params)):
        np.testing.assert_array_equal(a, np.asarray(b))
    # policy moved
    diffs = [np.max(np.abs(np.asarray(a) - np.asarray(b)))
             for a, b in zip(jax.tree.leaves(ref_before),
                             jax.tree.leaves(tr.params))]
    assert max(diffs) > 0


def test_no_swap_keeps_weights_on_device():
    tr = _trainer(use_allgather_swap=False)
    st = tr.iteration(global_batch=4)
    assert st.reshard["d2h_bytes"] == 0


def test_central_buffer_variant_runs():
    tr = _trainer(use_transfer_dock=False)
    st = tr.iteration(global_batch=4)
    assert np.isfinite(st.loss)
    assert tr.dock.name == "central_replay_buffer"


def test_dapo_variant_runs():
    tr = _trainer()
    tr.rl = tr.rl.replace(algorithm="dapo")
    st = tr.iteration(global_batch=4)
    assert np.isfinite(st.loss)


def test_throughput_formula():
    tr = _trainer()
    st = tr.iteration(global_batch=4)
    t = tr.throughput(st, 4, num_devices=2)
    toks = 4 * 2 * (12 + 8)
    ete = st.gen_time + st.infer_time + st.update_time
    assert t == pytest.approx(toks / 2 / ete, rel=1e-6)
