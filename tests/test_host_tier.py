"""Host-memory KV tier (repro.serve.host_tier): swap, don't recompute.

The contracts under test:

  * tier store — ``HostKVTier`` round-trips block bytes exactly (spill is
    ``device_get``, swap-in is ``device_put``; no arithmetic touches the
    rows), evicts LRU when full, and one prefix key lives in exactly one
    tier at a time;
  * spill policy — ``PagedKVCache.alloc()`` spills only PREFILL-provenance
    blocks on reclaim; decode-tainted blocks (``mark_decode_write``) are
    dropped exactly as without the tier;
  * bit-identity — greedy gen AND gen_logp are bitwise invariant to the
    tier being on or off, across preemptions, budget suspends and
    mid-sequence resumes (the tier's headline contract: swapped bytes ==
    the bytes recompute would have produced);
  * the win — with the pool starved, swap re-admission issues strictly
    fewer prefill tokens than recompute re-admission;
  * footprint — the tier adds ZERO device memory: pool shapes are
    identical with and without it, and the store is host numpy;
  * integration — engine stats expose the ``serve.swap.*`` counters, a
    params change flushes the host index, the trainer knob
    (``RLConfig.serve_host_tier_blocks``) reaches the engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve.engine import ServingEngine
from repro.serve.host_tier import HostKVTier
from repro.serve.paged_cache import PagedKVCache, prefix_key

TOK = ByteTokenizer()


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(b, pl, seed=0):
    return np.random.RandomState(seed).randint(0, 250, (b, pl)).astype(np.int32)


def _engine(cfg, max_new, **kw):
    return ServingEngine(cfg, max_new=max_new, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True, **kw)


def _rows(cfg, bs, seed):
    shp = (cfg.num_layers, bs, cfg.num_kv_heads, cfg.head_dim)
    r = np.random.RandomState(seed)
    return (jnp.asarray(r.randn(*shp).astype(np.float32)),
            jnp.asarray(r.randn(*shp).astype(np.float32)))


# ---------------------------------------------------------------------------
# tier store: async roundtrip, LRU, key exclusivity
# ---------------------------------------------------------------------------

def test_put_take_roundtrip_byte_exact(dense_setup):
    """Spill -> host store -> staging -> swap-in reproduces the device
    block's bytes exactly, through the async engine's full path."""
    cfg, _, _ = dense_setup
    tier = HostKVTier(cfg, num_blocks=2, block_size=4)
    k, v = _rows(cfg, 4, seed=0)
    key = prefix_key(b"", np.arange(4))
    tier.put(key, k, v)
    tier.swap.drain()
    assert len(tier) == 1 and tier.lookup(key) is not None
    stage = tier.take(key)
    assert stage is not None
    flat = jnp.arange(4, dtype=jnp.int32)
    tier.swap.submit_in(flat, stage)
    tier.swap.drain()
    [(got_flat, got_k, got_v)] = tier.swap.pop_ready()
    np.testing.assert_array_equal(np.asarray(got_flat), np.asarray(flat))
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(v))
    assert tier.lookup(key) is None, "take() must drop the index entry"
    tier.check_consistent()
    tier.close()


def test_lru_eviction_and_recency_refresh(dense_setup):
    """A full store evicts the least-recently-USED key; lookup refreshes
    recency; duplicate put of a resident key is a no-op."""
    cfg, _, _ = dense_setup
    tier = HostKVTier(cfg, num_blocks=2, block_size=4)
    keys = [prefix_key(b"", np.arange(4) + i) for i in range(3)]
    k, v = _rows(cfg, 4, seed=1)
    tier.put(keys[0], k, v)
    tier.put(keys[1], k, v)
    assert tier.lookup(keys[0]) is not None     # refresh: k0 now hottest
    tier.put(keys[2], k, v)                     # evicts k1 (the LRU)
    assert tier.lookup(keys[1]) is None
    assert tier.lookup(keys[0]) is not None
    assert tier.lookup(keys[2]) is not None
    assert tier.metrics.value("serve.swap.host_evictions") == 1
    before = tier.metrics.value("serve.swap.out_blocks")
    tier.put(keys[0], k, v)                     # already resident: no-op
    assert tier.metrics.value("serve.swap.out_blocks") == before
    tier.flush()
    assert len(tier) == 0
    tier.check_consistent()
    tier.close()


def test_host_tier_rejects_bad_sizes(dense_setup):
    cfg, _, _ = dense_setup
    with pytest.raises(ValueError):
        HostKVTier(cfg, num_blocks=0, block_size=4)
    tier = HostKVTier(cfg, num_blocks=2, block_size=8)
    with pytest.raises(ValueError):
        PagedKVCache(cfg, num_blocks=4, block_size=4,
                     max_blocks_per_seq=4, host=tier)
    tier.close()


# ---------------------------------------------------------------------------
# cache integration: spill on reclaim, provenance filter, swap-in
# ---------------------------------------------------------------------------

def test_reclaim_spills_and_swapin_restores_bits(dense_setup):
    """An indexed block's rows survive reclaim in the host tier and come
    back bit-exact via swap_in; the key moves between tiers, never living
    in both."""
    cfg, _, _ = dense_setup
    tier = HostKVTier(cfg, num_blocks=4, block_size=4)
    pc = PagedKVCache(cfg, num_blocks=2, block_size=4,
                      max_blocks_per_seq=2, host=tier)
    k, v = _rows(cfg, 4, seed=2)
    key = prefix_key(b"", np.arange(4))
    b = pc.alloc()
    rows = pc._block_rows(b)
    pc.pool_k = pc.pool_k.at[:, rows].set(k)
    pc.pool_v = pc.pool_v.at[:, rows].set(v)
    pc.register(key, b)
    pc.free([b])
    # reclaim every block: the indexed one spills instead of dropping
    c1, c2 = pc.alloc(), pc.alloc()
    assert pc.lookup(key) is None and pc.lookup_host(key) is not None
    pc.free([c1])
    b2 = pc.swap_in(key)
    assert b2 is not None
    assert pc.lookup(key) == b2 and pc.lookup_host(key) is None
    np.testing.assert_array_equal(
        np.asarray(pc.pool_k[:, pc._block_rows(b2)]), np.asarray(k))
    np.testing.assert_array_equal(
        np.asarray(pc.pool_v[:, pc._block_rows(b2)]), np.asarray(v))
    # a missing key is a clean miss, not an error
    assert pc.swap_in(prefix_key(b"", np.arange(4) + 9)) is None
    tier.close()


def test_decode_tainted_blocks_never_spill(dense_setup):
    """A block a decode step wrote into is dropped on reclaim (its bytes
    are not prefill-reproducible); its prefill-provenance sibling spills."""
    cfg, _, _ = dense_setup
    tier = HostKVTier(cfg, num_blocks=4, block_size=4)
    pc = PagedKVCache(cfg, num_blocks=2, block_size=4,
                      max_blocks_per_seq=2, host=tier)
    ka, kb = (prefix_key(b"", np.arange(4) + i) for i in range(2))
    a, b = pc.alloc(), pc.alloc()
    pc.register(ka, a)
    pc.register(kb, b)
    pc.mark_decode_write(b)
    pc.mark_decode_write(pc.null_block)     # null-block writes are inert
    pc.free([a, b])
    pc.alloc(), pc.alloc()                  # reclaim both
    assert pc.lookup_host(ka) is not None, "prefill block should spill"
    assert pc.lookup_host(kb) is None, "decode-tainted block must not spill"
    assert not pc._decode_written, "taint must die with the content"
    tier.close()


# ---------------------------------------------------------------------------
# bit-identity: tier on == tier off, under preemption + suspend/resume
# ---------------------------------------------------------------------------

def _sweep(cfg, params, host_blocks):
    """Deterministic starved-pool workload: staggered arrivals, preemptions,
    budget suspends with mid-sequence resume.  No prefill chunking — swap-in
    registration timing matches recompute registration timing only when the
    whole tail prefills in one admission step (docs/serving.md)."""
    pl, mn = 12, 10
    pool = [p for p in _prompts(3, pl, seed=21)]
    eng = _engine(cfg, mn, max_slots=3, block_size=4, num_blocks=14,
                  max_seq_len=pl + mn, host_tier_blocks=host_blocks)
    arrivals = [(0, 0), (0, 1), (1, 2), (2, 0), (3, 1), (3, 0), (5, 2),
                (7, 1)]
    outs, steps = [], 0
    while arrivals or not eng.sched.idle:
        while arrivals and arrivals[0][0] <= steps:
            eng.submit(pool[arrivals.pop(0)[1]])
        outs.extend(eng.step(params))
        eng.sched.check_invariants()
        steps += 1
        assert steps < 500
    budgets = [2, 5, 3, 4]
    pending = set()
    for i, bud in enumerate(budgets):
        pending.add(eng.submit(pool[i % 3], max_new=mn, budget=bud))
    rounds = 0
    while pending:
        finished, resum = eng.run_to_budget(params)
        eng.sched.check_invariants()
        for o in finished:
            pending.discard(o.rid)
            outs.append(o)
        for req in resum:
            pending.discard(req.rid)
            pending.add(eng.submit(req.prompt, generated=req.generated,
                                   max_new=mn - len(req.generated),
                                   budget=budgets[rounds % 4]))
        rounds += 1
        assert rounds <= 16
    stats = eng.stats()
    eng.close()
    return outs, stats


def test_greedy_bitwise_identical_tier_on_off(dense_setup):
    """THE tier contract: the same workload, pool starved into preemptions
    and suspend/resume churn, produces bitwise-identical greedy tokens AND
    logprobs with the host tier on vs off — swapped-in bytes are exactly
    the bytes recompute would have written."""
    cfg, _, params = dense_setup
    off, off_stats = _sweep(cfg, params, 0)
    on, on_stats = _sweep(cfg, params, 24)
    assert off_stats["preemptions"] > 0, "pool was never starved"
    assert on_stats["swap_in_blocks"] > 0, "tier never exercised"
    assert on_stats["preempt_swap"] > 0
    d_off = {o.rid: o for o in off}
    d_on = {o.rid: o for o in on}
    assert sorted(d_off) == sorted(d_on)
    for rid in d_off:
        np.testing.assert_array_equal(np.asarray(d_off[rid].gen),
                                      np.asarray(d_on[rid].gen))
        np.testing.assert_array_equal(d_off[rid].gen_logp,
                                      d_on[rid].gen_logp)


def test_swap_readmission_cheaper_than_recompute(dense_setup):
    """The tentpole win: re-admitting a preempted request via swap-in
    issues strictly fewer prefill tokens than recompute re-admission."""
    cfg, _, params = dense_setup
    off, off_stats = _sweep(cfg, params, 0)
    on, on_stats = _sweep(cfg, params, 24)
    assert on_stats["readmit_prefill_tokens"] < \
        off_stats["readmit_prefill_tokens"]
    # preemption classification follows the memory system
    assert off_stats["preempt_swap"] == 0
    assert off_stats["preempt_recompute"] == off_stats["preemptions"]
    assert on_stats["preempt_swap"] > 0
    # byte counters are exact multiples of the block payload
    probe = HostKVTier(cfg, num_blocks=1, block_size=4)
    bb = probe.block_bytes
    probe.close()
    assert on_stats["swap_out_bytes"] == on_stats["swap_out_blocks"] * bb
    assert on_stats["swap_in_bytes"] == on_stats["swap_in_blocks"] * bb
    assert off_stats["swap_out_blocks"] == 0
    assert off_stats["host_tier_blocks"] == 0


# ---------------------------------------------------------------------------
# footprint + config plumbing
# ---------------------------------------------------------------------------

def test_device_pool_footprint_unchanged(dense_setup):
    """The tier must cost ZERO device memory: identical pool shapes with
    and without it, and the store lives in host numpy."""
    cfg, _, params = dense_setup
    prompt = _prompts(1, 8, seed=3)[0]
    shapes = {}
    for host in (0, 16):
        eng = _engine(cfg, 4, max_slots=2, block_size=4, num_blocks=6,
                      max_seq_len=12, host_tier_blocks=host)
        eng.submit(prompt)
        eng.drain(params)
        shapes[host] = (eng.cache.pool_k.shape, eng.cache.pool_v.shape)
        if host:
            assert isinstance(eng.host_tier.store_k, np.ndarray)
            assert isinstance(eng.host_tier.store_v, np.ndarray)
            assert eng.host_tier.host_bytes == 2 * eng.host_tier.store_k.nbytes
        eng.close()
    assert shapes[0] == shapes[16]


def test_host_tier_requires_prefix_cache(dense_setup):
    cfg, _, _ = dense_setup
    with pytest.raises(ValueError, match="prefix_cache"):
        _engine(cfg, 4, max_slots=2, block_size=4,
                prefix_cache=False, host_tier_blocks=8)


def test_params_change_flushes_host_tier(dense_setup):
    """Stale-weights KV must never swap back in: a params change empties
    the host index along with the device index."""
    cfg, _, params = dense_setup
    eng = _engine(cfg, 10, max_slots=3, block_size=4, num_blocks=14,
                  max_seq_len=22, host_tier_blocks=24)
    for p in _prompts(3, 12, seed=21):
        for _ in range(2):
            eng.submit(p)
    eng.drain(params)
    assert len(eng.host_tier) > 0, "workload never spilled"
    swapped = eng.stats()["swap_in_blocks"]
    params2 = jax.tree_util.tree_map(lambda a: a + 0, params)
    # one request, no pool pressure: the only way a swap-in could happen
    # now is a STALE host hit surviving the weights change
    eng.submit(_prompts(3, 12, seed=21)[0])
    eng.drain(params2)
    assert eng.stats()["swap_in_blocks"] == swapped, \
        "stale-weights host KV satisfied a match after the flush"
    eng.close()


def test_trainer_knob_reaches_engine():
    """RLConfig.serve_host_tier_blocks flows through ActorWorker to the
    serving engine."""
    from repro.configs.base import RLConfig
    from repro.core.trainer import GRPOTrainer
    from repro.data.prompts import PromptDataset, pattern_task

    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=8,
                  rollout_engine="serving", serve_max_slots=4,
                  serve_block_size=4, serve_host_tier_blocks=8)
    ds = PromptDataset(pattern_task(), max_prompt_len=rl.max_prompt_len,
                       seed=0)
    tr = GRPOTrainer(cfg, rl, ds, num_nodes=2, seed=0)
    tr.iteration(1)
    eng = tr.actor.engine
    assert isinstance(eng, ServingEngine)
    assert eng.host_tier is not None
    assert eng.stats()["host_tier_blocks"] == 8
