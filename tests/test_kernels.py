"""Per-kernel correctness: Pallas (interpret mode) vs the pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, gmm, ops, ref, rmsnorm, rope, swiglu

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("rows,d", [(8, 64), (64, 256), (33, 128), (128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(rows, d, dtype, rng):
    x = jax.random.normal(rng, (rows, d), dtype)
    w = jax.random.normal(jax.random.fold_in(rng, 1), (d,), dtype)
    out = rmsnorm.rmsnorm(x, w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref.rmsnorm(x, w), np.float32),
        **TOL[dtype])


@pytest.mark.parametrize("rows,f", [(16, 64), (64, 512), (100, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swiglu(rows, f, dtype, rng):
    g = jax.random.normal(rng, (rows, f), dtype)
    u = jax.random.normal(jax.random.fold_in(rng, 1), (rows, f), dtype)
    out = swiglu.swiglu(g, u, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref.swiglu(g, u), np.float32),
        **TOL[dtype])


@pytest.mark.parametrize("b,s,h,d", [(2, 16, 4, 32), (1, 64, 8, 64),
                                     (3, 24, 2, 128)])
def test_rope(b, s, h, d, rng):
    x = jax.random.normal(rng, (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos, sin = ops.rope_tables(pos, d, 10_000.0)
    out = rope.apply_rope(x, cos, sin, interpret=True)
    want = ref.rope(x, cos[:, :, None, :], sin[:, :, None, :])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,sq,h,kv,d", [
    (2, 32, 8, 2, 16), (1, 64, 4, 4, 32), (2, 128, 8, 1, 64)])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, sq, h, kv, d, window, dtype, rng):
    q = jax.random.normal(rng, (b, sq, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, sq, kv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, sq, kv, d), dtype)
    out = flash_attention.flash_attention(
        q, k, v, causal=True, window=window, interpret=True,
        block_q=16, block_k=16)
    want = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_flash_noncausal(rng):
    q = jax.random.normal(rng, (2, 32, 4, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 4, 16))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 32, 4, 16))
    out = flash_attention.flash_attention(q, k, v, causal=False,
                                          interpret=True, block_q=16)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,d,f,e,tile", [(256, 32, 64, 4, 64),
                                          (128, 64, 128, 2, 32),
                                          (512, 16, 32, 8, 64)])
def test_gmm(t, d, f, e, tile, rng):
    # group sizes: tile-aligned (the kernel contract), incl. an empty group
    sizes = np.zeros(e, np.int32)
    remaining = t
    for i in range(e - 1):
        take = min(remaining, tile * (i % 3))
        sizes[i] = take
        remaining -= take
    sizes[-1] = remaining
    gs = jnp.asarray(sizes)
    x = jax.random.normal(rng, (t, d))
    w = jax.random.normal(jax.random.fold_in(rng, 1), (e, d, f))
    out = gmm.gmm(x, w, gs, tile_t=tile, interpret=True)
    want = ref.gmm(x, w, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ops_attention_grads_match_naive(rng):
    """custom-VJP flash backward == autodiff through the naive oracle."""
    q = jax.random.normal(rng, (2, 32, 8, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 32, 2, 16))

    def f_ops(q, k, v):
        return (ops.attention(q, k, v, causal=True, window=8) ** 2).sum()

    def f_ref(q, k, v):
        return (ref.attention(q, k, v, causal=True, window=8) ** 2).sum()

    g1 = jax.grad(f_ops, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_decode_attention_matches_last_position(rng):
    q = jax.random.normal(rng, (2, 1, 8, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 32, 2, 16))
    valid = jnp.ones((2, 32), bool)
    out = ops.decode_attention(q, k, v, valid)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
