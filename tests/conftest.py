import jax
import pytest

# CPU container: high matmul precision so allclose tolerances are meaningful.
jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
