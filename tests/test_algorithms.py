"""GRPO / PPO / DAPO algorithm-level unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RLConfig
from repro.core import grpo, ppo


def test_token_logprobs_manual(rng):
    logits = jax.random.normal(rng, (2, 5, 7))
    tokens = jax.random.randint(jax.random.fold_in(rng, 1), (2, 5), 0, 7)
    lp = grpo.token_logprobs(logits, tokens)
    want = np.zeros((2, 4))
    ls = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    tk = np.asarray(tokens)
    for b in range(2):
        for t in range(4):
            want[b, t] = ls[b, t, tk[b, t + 1]]
    np.testing.assert_allclose(np.asarray(lp), want, rtol=1e-5, atol=1e-6)


def test_group_advantages_zero_mean_unit_std(rng):
    r = jax.random.normal(rng, (8, 16)) * 3 + 1
    adv = np.asarray(grpo.group_advantages(r))
    np.testing.assert_allclose(adv.mean(axis=1), 0, atol=1e-5)
    np.testing.assert_allclose(adv.std(axis=1), 1, atol=1e-2)


def test_grpo_loss_zero_at_identity():
    """ratio == 1 and ref == policy -> pure pg term == -adv (clipped), and
    the KL term vanishes."""
    rl = RLConfig(kl_coef=0.5)
    b, t = 4, 6
    logp = jnp.full((b, t), -1.0)
    mask = jnp.ones((b, t))
    adv = jnp.zeros((b,))
    loss, m = grpo.grpo_loss(logp, logp, logp, adv, mask, rl)
    assert abs(float(loss)) < 1e-6
    assert abs(float(m["kl"])) < 1e-7
    assert float(m["ratio_mean"]) == pytest.approx(1.0)


def test_grpo_clipping_bounds():
    rl = RLConfig(clip_eps=0.2)
    b, t = 1, 1
    old = jnp.zeros((b, t))
    mask = jnp.ones((b, t))
    adv = jnp.ones((b,))
    # ratio far above 1+eps: positive advantage gain is clipped at 1.2
    lp_hi = jnp.full((b, t), 2.0)
    loss_hi, _ = grpo.grpo_loss(lp_hi, old, old, adv, mask,
                                rl.replace(kl_coef=0.0))
    assert float(loss_hi) == pytest.approx(-1.2, rel=1e-5)
    # negative advantage with tiny ratio is NOT clipped on that side (min)
    loss_neg, _ = grpo.grpo_loss(lp_hi, old, old, -adv, mask,
                                 rl.replace(kl_coef=0.0))
    assert float(loss_neg) == pytest.approx(np.exp(2.0), rel=1e-5)


def test_dapo_decoupled_clip():
    rl = RLConfig(algorithm="dapo", clip_eps=0.2, clip_eps_high=0.28)
    old = jnp.zeros((1, 1))
    mask = jnp.ones((1, 1))
    adv = jnp.ones((1,))
    lp = jnp.full((1, 1), 2.0)
    loss, _ = grpo.grpo_loss(lp, old, old, adv, mask, rl)
    assert float(loss) == pytest.approx(-1.28, rel=1e-5)  # upper clip = 1.28


def test_kl_k3_positive(rng):
    rl = RLConfig(kl_coef=1.0)
    logp = jax.random.normal(rng, (4, 8))
    ref = jax.random.normal(jax.random.fold_in(rng, 1), (4, 8))
    _, m = grpo.grpo_loss(logp, logp, ref, jnp.zeros((4,)),
                          jnp.ones((4, 8)), rl)
    assert float(m["kl"]) > 0  # k3 estimator is non-negative


def test_gae_matches_naive(rng):
    b, t = 3, 12
    rewards = np.asarray(jax.random.normal(rng, (b, t)))
    values = np.asarray(jax.random.normal(jax.random.fold_in(rng, 1), (b, t)))
    mask = np.ones((b, t), np.float32)
    mask[:, -3:] = 0
    gamma, lam = 0.97, 0.93
    adv, ret = ppo.gae(jnp.asarray(rewards), jnp.asarray(values),
                       jnp.asarray(mask), gamma, lam)
    want = np.zeros((b, t))
    for bi in range(b):
        run = 0.0
        for ti in reversed(range(t)):
            nv = values[bi, ti + 1] if ti + 1 < t else 0.0
            delta = rewards[bi, ti] + gamma * nv * mask[bi, ti] - values[bi, ti]
            run = delta + gamma * lam * mask[bi, ti] * run
            want[bi, ti] = run
    np.testing.assert_allclose(np.asarray(adv), want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ret), want + values,
                               rtol=1e-4, atol=1e-5)


def test_pf_filter_keeps_extremes(rng):
    r = jnp.arange(16.0)
    w = np.asarray(ppo.pf_filter(r, keep_best=0.25, keep_worst=0.25))
    assert w[:4].sum() == 4      # worst quartile kept
    assert w[-4:].sum() == 4     # best quartile kept
    assert w[6:10].sum() == 0    # middle dropped


def test_ppo_value_clip(rng):
    rl = RLConfig(clip_eps=0.2)
    b, t = 2, 4
    z = jnp.zeros((b, t))
    mask = jnp.ones((b, t))
    vals = jnp.full((b, t), 1.0)
    old_vals = jnp.zeros((b, t))
    returns = jnp.full((b, t), 2.0)
    pg, vloss = ppo.ppo_losses(z, z, z, vals, old_vals, returns, mask, rl)
    # value moved 1.0 > eps from old: clipped branch (0.2 - 2)^2 dominates
    assert float(vloss) == pytest.approx(0.5 * max((1 - 2) ** 2,
                                                   (0.2 - 2) ** 2), rel=1e-5)
