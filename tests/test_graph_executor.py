"""Graph-executor tests: declaration validation, deterministic topological
replay, bit-identity of the GRPO/PPO graph runs against the pre-redesign
imperative stage sequencing, fusion on/off equivalence, and per-sample
streaming dispatch."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RLConfig
from repro.core import grpo
from repro.core.graph import (GraphExecutor, RLGraph, StageNode,
                              complete_groups)
from repro.core.partial import PartialRolloutTrainer, build_partial_graph
from repro.core.ppo_trainer import PPOTrainer, build_ppo_graph
from repro.core.resharding import ReshardLedger
from repro.core.trainer import GRPOTrainer, build_grpo_graph
from repro.core.transfer_dock import DispatchLedger, TransferDock
from repro.data.prompts import PromptDataset, pattern_task

TINY = ModelConfig(
    name="tiny", arch_type="dense", num_layers=2, d_model=128,
    vocab_size=512, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
    dtype="float32", remat=False)


def _ds():
    return PromptDataset(pattern_task(), max_prompt_len=12, seed=0)


def _rl(**kw):
    base = dict(num_generations=2, max_prompt_len=12, max_response_len=8,
                lr=1e-4, greedy=True)
    base.update(kw)
    return RLConfig(**base)


# ---------------------------------------------------------------------------
# declaration validation
# ---------------------------------------------------------------------------

def _noop(ctx, io):
    return None


def test_graph_rejects_duplicate_names():
    with pytest.raises(ValueError, match="duplicate"):
        RLGraph("g", [
            StageNode("a", 0, ("prompt",), ("x",), _noop),
            StageNode("a", 0, ("x",), (), _noop),
        ])


def test_graph_rejects_unproduced_input():
    with pytest.raises(ValueError, match="consumes 'y'"):
        RLGraph("g", [StageNode("a", 0, ("y",), (), _noop)])


def test_graph_rejects_cycles():
    with pytest.raises(ValueError, match="cycle"):
        RLGraph("g", [
            StageNode("a", 0, ("x",), ("y",), _noop),
            StageNode("b", 0, ("y",), ("x",), _noop),
        ], external=())


def test_graph_rejects_double_producer():
    with pytest.raises(ValueError, match="produced by both"):
        RLGraph("g", [
            StageNode("a", 0, ("prompt",), ("x",), _noop),
            StageNode("b", 0, ("prompt",), ("x",), _noop),
        ])


def test_builtin_graphs_validate_and_describe():
    for build in (build_grpo_graph, build_ppo_graph, build_partial_graph):
        g = build(0, 1, 2)
        order = [n.name for n in g.toposort()]
        assert order[0] == "actor_generation"
        assert order[-1] == "actor_update"
        txt = g.describe()
        for n in g.nodes:
            assert n.name in txt
        assert "layout=generation" in txt and "layout=update" in txt
        assert set(g.states()) == {n.name for n in g.nodes}


# ---------------------------------------------------------------------------
# pre-redesign imperative sequencing (verbatim stage order of the old
# trainers) — the bit-identity reference
# ---------------------------------------------------------------------------

def _legacy_grpo_iteration(tr, global_batch):
    rl = tr.rl
    G, N = global_batch, rl.num_generations
    total = G * N
    tr.dock.clear()
    prompts, plens, metas = tr.dataset.sample(G)
    pl = prompts.shape[1]
    prompts_rep = np.repeat(prompts, N, axis=0)
    metas_rep = [metas[i // N] for i in range(total)]
    tr.dock.put("prompt", list(range(total)), prompts_rep, src_node=0)

    gen_params, stash, led = tr.resharder.to_generation(tr.params)
    tr.params = None

    ready = tr.dock.request_metadata("actor_generation", ["prompt"])
    pbatch = tr.dock.get("actor_generation", "prompt", ready,
                         dst_node=tr.actor.node)
    tr.key, k = jax.random.split(tr.key)
    rollout = tr.actor.generate(gen_params, pbatch, k)
    tr.dock.put("tokens", ready, rollout.tokens, src_node=tr.actor.node)
    tr.dock.put("response_mask", ready, rollout.response_mask,
                src_node=tr.actor.node)
    tr.dock.mark_consumed("actor_generation", ready)
    del gen_params
    tr.params, led = tr.resharder.to_update(stash, led)

    ready = tr.dock.request_metadata("actor_inference", ["tokens"])
    toks = tr.dock.get("actor_inference", "tokens", ready, dst_node=0)
    old_logp = tr.actor.old_logprobs(tr.params, toks)
    tr.dock.put("old_logp", ready, old_logp, src_node=0)
    tr.dock.mark_consumed("actor_inference", ready)

    ready_ref = tr.dock.request_metadata("ref_inference", ["tokens"])
    toks_ref = tr.dock.get("ref_inference", "tokens", ready_ref,
                           dst_node=tr.ref.node)
    ready_rw = tr.dock.request_metadata("reward", ["tokens"])
    toks_rw = tr.dock.get("reward", "tokens", ready_rw,
                          dst_node=tr.reward.node)
    ref_logp = tr.ref.logprobs(toks_ref)
    rewards = tr.reward.score([metas_rep[i] for i in ready_rw], toks_rw, pl)
    tr.dock.put("ref_logp", ready_ref, ref_logp, src_node=tr.ref.node)
    tr.dock.mark_consumed("ref_inference", ready_ref)
    adv = np.asarray(
        grpo.group_advantages(jnp.asarray(rewards.reshape(G, N)))
    ).reshape(-1)
    tr.dock.put("advantages", ready_rw, adv[:, None],
                src_node=tr.reward.node)
    tr.dock.mark_consumed("reward", ready_rw)

    ready = tr.dock.request_metadata(
        "actor_update",
        ["tokens", "response_mask", "old_logp", "ref_logp", "advantages"])
    mb = tr.microbatch or len(ready)
    losses = []
    for lo in range(0, len(ready), mb):
        sel = ready[lo:lo + mb]
        batch = {
            "tokens": jnp.asarray(tr.dock.get(
                "actor_update", "tokens", sel, 0)),
            "response_mask": jnp.asarray(tr.dock.get(
                "actor_update", "response_mask", sel, 0)),
            "old_logp": jnp.asarray(tr.dock.get(
                "actor_update", "old_logp", sel, 0)),
            "ref_logp": jnp.asarray(tr.dock.get(
                "actor_update", "ref_logp", sel, 0)),
            "advantages": jnp.asarray(tr.dock.get(
                "actor_update", "advantages", sel, 0))[:, 0],
        }
        tr.params, tr.opt_state, metrics = tr.train_step(
            tr.params, tr.opt_state, batch)
        losses.append(float(metrics["loss"]))
    tr.dock.mark_consumed("actor_update", ready)
    return rewards, losses


def _legacy_ppo_iteration(tr, global_batch):
    rl = tr.rl
    G = global_batch
    tr.dock.clear()
    prompts, plens, metas = tr.dataset.sample(G)
    pl = prompts.shape[1]
    idxs = list(range(G))
    tr.dock.put("prompt", idxs, prompts, src_node=0)

    gen_params, stash, led = tr.resharder.to_generation(tr.params)
    tr.params = None
    ready = tr.dock.request_metadata("actor_generation", ["prompt"])
    pb = tr.dock.get("actor_generation", "prompt", ready, dst_node=0)
    tr.key, k = jax.random.split(tr.key)
    roll = tr.actor.generate(gen_params, pb, k)
    tr.dock.put("tokens", ready, roll.tokens, src_node=0)
    tr.dock.put("response_mask", ready, roll.response_mask, src_node=0)
    tr.dock.mark_consumed("actor_generation", ready)
    del gen_params
    tr.params, led = tr.resharder.to_update(stash, led)

    toks = tr.dock.get("actor_inference", "tokens", idxs, dst_node=0)
    mask = tr.dock.get("actor_inference", "response_mask", idxs, 0)
    old_logp = tr.actor.old_logprobs(tr.params, toks)
    values = np.asarray(
        tr._values(tr.params, {"tokens": jnp.asarray(toks)}), np.float32)
    ref_logp = tr.ref.logprobs(toks)
    rewards = tr.reward.score(metas, toks, pl)

    kl = old_logp - ref_logp
    tok_rewards = -rl.kl_coef * kl
    m = mask[:, 1:]
    last = np.maximum(m.cumsum(1).argmax(1), 0)
    tok_rewards[np.arange(G), last] += rewards
    from repro.core import ppo
    adv, ret = ppo.gae(jnp.asarray(tok_rewards),
                       jnp.asarray(values[:, 1:] * m),
                       jnp.asarray(m), rl.gamma, rl.gae_lambda)
    adv = np.asarray(adv)
    if tr.pf:
        w = np.asarray(ppo.pf_filter(jnp.asarray(rewards)))
        adv = adv * w[:, None]
    pad = lambda a: np.concatenate(                        # noqa: E731
        [np.zeros((G, 1), np.float32), a], axis=1)
    tb = {
        "tokens": jnp.asarray(toks),
        "response_mask": jnp.asarray(mask),
        "old_logp": jnp.asarray(old_logp),
        "values": jnp.asarray(pad(np.asarray(values[:, 1:]))),
        "old_values": jnp.asarray(pad(np.asarray(values[:, 1:]))),
        "advantages_tok": jnp.asarray(pad(adv)),
        "returns": jnp.asarray(pad(np.asarray(ret))),
    }
    tr.params, tr.opt_state, metrics = tr.train_step(
        tr.params, tr.opt_state, tb)
    return rewards, [float(metrics["loss"])]


def _assert_params_equal(pa, pb):
    la, lb = jax.tree.leaves(pa), jax.tree.leaves(pb)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bit-identity: graph run == pre-redesign sequencing (greedy decoding)
# ---------------------------------------------------------------------------

def test_grpo_graph_bit_identical_to_legacy():
    tg = GRPOTrainer(TINY, _rl(), _ds(), num_nodes=4, seed=0, microbatch=3)
    tl = GRPOTrainer(TINY, _rl(), _ds(), num_nodes=4, seed=0, microbatch=3)
    for it in range(2):
        st = tg.iteration(global_batch=4)
        rewards, losses = _legacy_grpo_iteration(tl, 4)
        _assert_params_equal(tg.params, tl.params)
        assert st.loss == pytest.approx(float(np.mean(losses)), abs=0)
        assert st.reward_mean == pytest.approx(float(np.mean(rewards)),
                                               abs=0)


def test_ppo_graph_bit_identical_to_legacy():
    tg = PPOTrainer(TINY, _rl(), _ds(), num_nodes=4, seed=0)
    tl = PPOTrainer(TINY, _rl(), _ds(), num_nodes=4, seed=0)
    for it in range(2):
        st = tg.iteration(global_batch=4)
        rewards, losses = _legacy_ppo_iteration(tl, 4)
        _assert_params_equal(tg.params, tl.params)
        assert st.loss == pytest.approx(losses[0], abs=0)


# ---------------------------------------------------------------------------
# deterministic topological replay + fusion on/off equivalence
# ---------------------------------------------------------------------------

def _check_topological(graph, trace, external_idxs):
    produced = {f: set(external_idxs) for f in graph.external}
    nodes = {n.name: n for n in graph.nodes}
    for name, idxs in trace:
        node = nodes[name]
        for f in node.inputs:
            missing = set(idxs) - produced.get(f, set())
            assert not missing, (
                f"{name} dispatched on {sorted(missing)} before {f!r} was "
                f"produced")
        for f in node.outputs:
            produced.setdefault(f, set()).update(idxs)


def test_trace_deterministic_and_topological():
    runs = []
    for _ in range(2):
        tr = GRPOTrainer(TINY, _rl(), _ds(), num_nodes=4, seed=0)
        st = tr.iteration(global_batch=4)
        runs.append((tr, st))
    (t0, s0), (t1, s1) = runs
    assert s0.trace == s1.trace          # deterministic replay
    assert len(s0.trace) == len(t0.graph.nodes)   # each stage ran once
    _check_topological(t0.graph, s0.trace, range(8))
    _assert_params_equal(t0.params, t1.params)


def test_fusion_on_off_equivalent():
    ta = GRPOTrainer(TINY, _rl(stage_fusion=True), _ds(), num_nodes=4,
                     seed=0)
    tb = GRPOTrainer(TINY, _rl(stage_fusion=False), _ds(), num_nodes=4,
                     seed=0)
    for it in range(2):
        sa = ta.iteration(global_batch=4)
        sb = tb.iteration(global_batch=4)
        assert sa.trace == sb.trace      # fusion changes concurrency only
        _assert_params_equal(ta.params, tb.params)
        assert sa.loss == pytest.approx(sb.loss, abs=0)
    # fusion actually co-scheduled the independent inference consumers:
    # one round dispatched actor_inference + ref_inference + reward
    names = [n for n, _ in sa.trace]
    i_inf = names.index("actor_inference")
    assert {"ref_inference", "reward"} <= set(names[i_inf:i_inf + 3])


class _BucketLoopPartial(PartialRolloutTrainer):
    """The RETIRED partial-rollout implementation, kept verbatim as the
    bit-identity reference: an ad-hoc bucket loop over the synchronized
    engine that re-prefills equal-length prefixes together, mutates the
    engine-wide cap, and can overshoot the response cap."""
    actor_engine_kind = "sync"

    def _build_graph(self):
        from repro.core.graph import derive_nodes
        base = super()._build_graph()
        return RLGraph(base.name, derive_nodes(base, {
            "actor_generation": dict(fn=_BucketLoopPartial._stage_generate),
        }))

    def _stage_generate(self, io):
        from collections import defaultdict
        rl = self.rl
        pl = rl.max_prompt_len
        cap = pl + rl.max_response_len
        buckets = defaultdict(list)
        for idx in io.idxs:
            buckets[pl + self.partials[idx].ngen].append(idx)
        finished = []
        for plen, idxs in sorted(buckets.items()):
            batch = np.stack([
                np.concatenate([self.partials[i].prompt,
                                np.asarray(self.partials[i].generated,
                                           np.int32)]) for i in idxs])
            self.key, k = jax.random.split(self.key)
            eng = self.actor.engine
            eng.max_new = self.budget
            roll = eng.generate(self.gen_params, batch, k)
            for j, idx in enumerate(idxs):
                st = self.partials[idx]
                n = int(roll.lengths[j])
                new_tokens = roll.tokens[j, plen:plen + n]
                st.generated.extend(int(t) for t in new_tokens)
                hit_eos = bool((new_tokens == self.tok.eos_id).any())
                if hit_eos or st.ngen >= rl.max_response_len:
                    toks = np.concatenate(
                        [st.prompt, np.asarray(st.generated, np.int32)])
                    row = np.full((cap,), self.tok.pad_id, np.int32)
                    row[:len(toks[:cap])] = toks[:cap]
                    mask = np.zeros((cap,), np.float32)
                    mask[pl:pl + st.ngen] = 1.0
                    io.put("tokens", [idx], row[None])
                    io.put("response_mask", [idx], mask[None])
                    finished.append(idx)
                    del self.partials[idx]
        io.consumed = finished
        return None


def test_partial_serving_bit_identical_to_bucket_loop():
    """Acceptance: serving-backed partial generation (submit/run_to_budget,
    per-request budgets, on_finish streaming) reproduces the retired bucket
    loop bit-for-bit under greedy decoding — budget 6 against cap 16 also
    crosses the overshoot boundary the old loop papered over."""
    rl = _rl(max_response_len=16, partial_rollout=True)
    ta = PartialRolloutTrainer(TINY, rl, _ds(), budget=6, num_nodes=4,
                               seed=0)
    tb = _BucketLoopPartial(TINY, rl, _ds(), budget=6, num_nodes=4, seed=0)
    assert ta.actor.engine_kind == "serving"
    assert tb.actor.engine_kind == "sync"
    for it in range(3):
        sa = ta.iteration(global_batch=4)
        sb = tb.iteration(global_batch=4)
        assert np.isfinite(sa.loss) and np.isfinite(sb.loss)
        assert ta.pending_partials == tb.pending_partials
        _assert_params_equal(ta.params, tb.params)
    # the serving trainer never clobbered its engine-wide cap
    assert ta.actor.engine.max_new == rl.max_response_len


def test_partial_graph_lifecycle_matches_contract():
    rl = _rl(max_response_len=16, partial_rollout=True)
    tr = PartialRolloutTrainer(TINY, rl, _ds(), budget=6, num_nodes=4,
                               seed=0)
    pendings, prev_ngen = [], {}
    for it in range(4):
        st = tr.iteration(global_batch=4)
        pendings.append(tr.pending_partials)
        assert np.isfinite(st.loss)
        _check_topological(tr.graph, st.trace,
                           range(tr._next_idx))
        # one budget quantum per iteration: the generation node dispatched
        # exactly once and no sequence advanced more than `budget` tokens
        names = [n for n, _ in st.trace]
        assert names.count("actor_generation") == 1
        for idx, p in tr.partials.items():
            assert p.ngen - prev_ngen.get(idx, 0) <= 6
        prev_ngen = {idx: p.ngen for idx, p in tr.partials.items()}
    assert pendings[0] == 8
    consumed = tr.dock.controllers["actor_update"].consumed
    assert len(consumed) % rl.num_generations == 0 and len(consumed) > 0


# ---------------------------------------------------------------------------
# sample-granularity streaming dispatch (synthetic serving stage)
# ---------------------------------------------------------------------------

class _FakeResharder:
    def to_generation(self, params):
        return params, ("device", params), ReshardLedger()

    def to_update(self, stash, led=None):
        return stash[1], led or ReshardLedger()


class _FakeActor:
    engine_kind = "serving"
    node = 0


class _Ctx:
    def __init__(self, rl):
        self.rl = rl
        self.actor = _FakeActor()
        self.resharder = _FakeResharder()
        self.params = {"w": np.zeros(1, np.float32)}
        self.gen_params = None
        self.batches = []


def test_streaming_starts_downstream_at_sample_granularity():
    n = 5

    def gen_fn(ctx, io):
        # emit one sample at a time, like ServingEngine.on_finish
        for idx in io.idxs:
            io.put("tokens", [idx], np.full((1, 4), idx, np.int32))
            time.sleep(0.03)
        return None

    def sink_fn(ctx, io):
        ctx.batches.append(tuple(io.idxs))
        return {"out": np.ones((len(io.idxs), 1), np.float32)}

    graph = RLGraph("stream-demo", [
        StageNode("gen", 0, ("prompt",), ("tokens",), gen_fn,
                  layout="generation", timing="gen"),
        StageNode("sink", 1, ("tokens",), ("out",), sink_fn, stream=True),
    ])
    rl = RLConfig(stage_fusion=True)
    dock = TransferDock(2, graph.states(), DispatchLedger())
    dock.put("prompt", list(range(n)), np.zeros((n, 4), np.int32),
             src_node=0)
    ctx = _Ctx(rl)
    ex = GraphExecutor(dock, rl)
    run = ex.run(graph, ctx, expected=n)
    assert run.counts == {"gen": n, "sink": n}
    # downstream started BEFORE the generation barrier: more than one
    # sink dispatch, and the first one on a strict subset
    assert len(ctx.batches) >= 2
    assert len(ctx.batches[0]) < n
    assert sorted(i for b in ctx.batches for i in b) == list(range(n))
    # executor restored the update layout at drain
    assert ctx.params is not None and ctx.gen_params is None


def test_complete_groups_gate():
    assert complete_groups([0, 1, 2, 4, 5], 2) == [0, 1, 4, 5]
    assert complete_groups([3], 2) == []
    assert complete_groups([], 4) == []
    assert complete_groups([7, 6, 5, 4], 4) == [4, 5, 6, 7]
