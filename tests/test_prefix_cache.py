"""Prefix-cache block sharing + chunked prefill (repro.serve).

The contracts under test:

  * allocator — blocks are ref-counted; freeing only decrements; a freed
    block keeps its content + index entry until ``alloc()`` reclaims it
    (least-recently-freed first), at which point the entry dies;
  * sharing — N requests with the same prompt head prefill it ONCE
    (asserted via the engine's admitted-prefill token counter), including
    members admitted in the same wave, and partial-rollout resume re-matches
    its own suspended blocks;
  * bit-identity — greedy outputs (tokens AND gen_logp) are bitwise
    invariant to prefix sharing and to any prefill chunk size, and
    ``generate()`` keeps its bitwise contract with ``RolloutEngine``;
  * safety — a params change flushes the index (stale-weights KV is never
    matched), and scheduler/cache invariants hold under a randomized
    admit/evict/resume sweep.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.rollout import RolloutEngine
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve.engine import ServingEngine
from repro.serve.paged_cache import PagedKVCache, prefix_key
from repro.serve.scheduler import OutOfBlocksError

TOK = ByteTokenizer()


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(b, pl, seed=0):
    return np.random.RandomState(seed).randint(0, 250, (b, pl)).astype(np.int32)


def _engine(cfg, max_new, **kw):
    return ServingEngine(cfg, max_new=max_new, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True, **kw)


# ---------------------------------------------------------------------------
# allocator: refcounts, revival, eviction ordering
# ---------------------------------------------------------------------------

def test_refcount_share_free_revive_evict(dense_setup):
    cfg, _, _ = dense_setup
    pc = PagedKVCache(cfg, num_blocks=3, block_size=4, max_blocks_per_seq=3)
    toks = np.arange(8, dtype=np.int32)
    key = prefix_key(b"", toks[:4])
    # chained keys identify the WHOLE prefix: same block tokens under a
    # different parent produce a different key
    assert key != prefix_key(prefix_key(b"", toks[4:]), toks[:4])
    a = pc.alloc()
    pc.register(key, a)
    assert pc.lookup(key) == a
    pc.share(a)
    assert pc.refcount(a) == 2
    pc.free([a])                       # one ref down: still resident
    assert pc.refcount(a) == 1 and pc.num_free == 2
    assert pc.lookup(key) == a
    pc.free([a])                       # now reclaimable, STILL indexed
    assert pc.refcount(a) == 0 and pc.num_free == 3
    assert pc.lookup(key) == a
    pc.share(a)                        # revival out of the free structure
    assert pc.refcount(a) == 1 and pc.num_free == 2
    pc.free([a])
    # eviction order is least-recently-freed first: the two never-used
    # blocks go before the freshly freed cached one
    b1, b2 = pc.alloc(), pc.alloc()
    assert a not in (b1, b2)
    assert pc.lookup(key) == a      # content still intact
    c = pc.alloc()                     # reclaims a -> index entry dies
    assert c == a
    assert pc.lookup(key) is None
    with pytest.raises(OutOfBlocksError):
        pc.alloc()
    pc.flush_index()
    assert pc._index == {} and pc._block_key == {}


def test_eviction_order_exact_under_revive_churn(dense_setup):
    """A freed block that gets revived and freed again must be evicted at
    its NEW position (most recently freed), not at its stale first-free
    deque slot — the epoch stamp invalidates the old entry."""
    cfg, _, _ = dense_setup
    pc = PagedKVCache(cfg, num_blocks=2, block_size=4, max_blocks_per_seq=2)
    b0, b1 = pc.alloc(), pc.alloc()
    pc.free([b0])                      # t1: b0 freed first
    pc.share(b0)                       # revived (stale deque entry remains)
    pc.free([b1])                      # t2
    pc.free([b0])                      # t3: b0 now MOST recently freed
    assert pc.alloc() == b1, "evicted the hotter block first"
    assert pc.alloc() == b0


def test_double_free_asserts(dense_setup):
    cfg, _, _ = dense_setup
    pc = PagedKVCache(cfg, num_blocks=2, block_size=4, max_blocks_per_seq=2)
    b = pc.alloc()
    pc.free([b])
    with pytest.raises(AssertionError):
        pc.free([b])


# ---------------------------------------------------------------------------
# group sharing: N samples per prompt prefill the head once
# ---------------------------------------------------------------------------

def test_group_prefills_shared_head_once(dense_setup):
    """8 requests for one prompt: the block-aligned head is prefilled by the
    first member only; every other member prefills just the divergent tail
    (the final partial block), whether admitted in the same wave or later."""
    cfg, _, params = dense_setup
    pl, mn, bs, n = 19, 8, 8, 8
    prompt = _prompts(1, pl, seed=1)[0]
    cont = _engine(cfg, mn, max_slots=4, block_size=bs, max_seq_len=pl + mn)
    for _ in range(n):
        cont.submit(prompt)
    outs = cont.drain(params)
    cont.sched.check_invariants()
    head = (pl - 1) // bs * bs                       # 16 shareable rows
    tail = pl - head                                 # 3-token tail each
    assert cont.prefill_tokens == pl + (n - 1) * tail
    assert cont.shared_prefill_tokens == (n - 1) * head
    # every member decodes the identical greedy stream
    gens = [np.asarray(o.gen) for o in sorted(outs, key=lambda o: o.rid)]
    lps = [o.gen_logp for o in sorted(outs, key=lambda o: o.rid)]
    for g, lp in zip(gens[1:], lps[1:]):
        np.testing.assert_array_equal(g, gens[0])
        np.testing.assert_array_equal(lp, lps[0])
    assert cont.cache.num_free == cont.cache.num_blocks


def test_block_aligned_prompt_keeps_one_tail_token(dense_setup):
    """A prompt that is an exact block multiple may not be matched whole:
    at least one token stays in the tail so admission has last-token logits
    to sample the first response token from."""
    cfg, _, params = dense_setup
    pl, mn, bs = 16, 6, 8
    prompt = _prompts(1, pl, seed=2)[0]
    cont = _engine(cfg, mn, max_slots=2, block_size=bs, max_seq_len=pl + mn)
    cont.submit(prompt)
    cont.submit(prompt)
    cont.drain(params)
    cont.sched.check_invariants()
    # member 2 re-prefills the whole LAST block (8 tokens), shares the first
    assert cont.prefill_tokens == pl + bs
    assert cont.shared_prefill_tokens == pl - bs


# ---------------------------------------------------------------------------
# bit-identity across sharing / chunking configurations
# ---------------------------------------------------------------------------

def test_sharing_and_chunking_bitwise_invariant(dense_setup):
    """Greedy gen AND gen_logp are bitwise identical across: no prefix
    cache, prefix cache, chunked prefill, and both combined — for a mixed
    workload of duplicate and distinct prompts."""
    cfg, _, params = dense_setup
    pl, mn, bs = 19, 10, 8
    ps = _prompts(2, pl, seed=3)
    subs = [ps[0], ps[0], ps[1], ps[0], ps[1]]

    def run(**kw):
        e = _engine(cfg, mn, max_slots=3, block_size=bs,
                    max_seq_len=pl + mn, **kw)
        for p in subs:
            e.submit(p)
        outs = {o.rid: o for o in e.drain(params)}
        e.sched.check_invariants()
        return e, outs

    base_e, base = run(prefix_cache=False)
    assert base_e.shared_prefill_tokens == 0
    for kw in (dict(prefix_cache=True),
               dict(prefix_cache=False, prefill_chunk=4),
               dict(prefix_cache=True, prefill_chunk=4),
               dict(prefix_cache=True, prefill_chunk=1)):
        e, outs = run(**kw)
        for rid in base:
            np.testing.assert_array_equal(np.asarray(base[rid].gen),
                                          np.asarray(outs[rid].gen))
            np.testing.assert_array_equal(base[rid].gen_logp,
                                          outs[rid].gen_logp)
        if kw.get("prefix_cache"):
            # 3 duplicate admissions x 16-row head — same-wave members
            # included (the rematch-before-first-chunk upgrade)
            assert e.shared_prefill_tokens == 3 * 16
        if kw.get("prefill_chunk"):
            assert e.max_step_prefill <= kw["prefill_chunk"]


def test_generate_bitcompat_with_sharing_and_chunking(dense_setup):
    """The PR-1 contract survives the new allocator: ``generate()`` over
    GRPO-style duplicated prompts, with prefix sharing AND chunked prefill
    enabled, stays BIT-identical (incl. gen_logp) to ``RolloutEngine``."""
    cfg, _, params = dense_setup
    pl, mn, n = 8, 12, 3
    prompts = np.repeat(_prompts(2, pl, seed=4), n, axis=0)   # 2 groups of 3
    sync = RolloutEngine(cfg, max_new=mn, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True)
    cont = _engine(cfg, mn, max_slots=len(prompts), block_size=4,
                   prefill_chunk=4)
    r1 = sync.generate(params, prompts, jax.random.PRNGKey(5))
    r2 = cont.generate(params, prompts, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    np.testing.assert_array_equal(r1.response_mask, r2.response_mask)
    np.testing.assert_array_equal(r1.gen_logp, r2.gen_logp)
    # group members 2..N shared the head blocks (8 rows each at bs=4)
    assert cont.shared_prefill_tokens == 2 * (n - 1) * 4


def test_generate_preemption_with_sharing_chunking_matches_rollout(
        dense_setup):
    """Starved pool: recompute-preemption refills run through the
    prefix-matched chunked path and still land on the sync engine's greedy
    tokens."""
    cfg, _, params = dense_setup
    b, pl, mn = 4, 8, 12
    prompts = _prompts(b, pl, seed=4)
    sync = RolloutEngine(cfg, max_new=mn, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True)
    cont = _engine(cfg, mn, max_slots=3, block_size=4, num_blocks=11,
                   max_seq_len=pl + mn, prefill_chunk=6)
    r1 = sync.generate(params, prompts, jax.random.PRNGKey(5))
    r2 = cont.generate(params, prompts, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    cont.sched.check_invariants()


# ---------------------------------------------------------------------------
# chunked prefill: per-step budget + decode interleaving
# ---------------------------------------------------------------------------

def test_chunk_budget_and_interleaving_mid_decode(dense_setup):
    """A max-length prompt admitted while another request decodes: no step
    spends more than ``prefill_chunk`` prefill tokens, and the running
    request keeps producing tokens while the long prompt chunks in."""
    cfg, _, params = dense_setup
    bs, chunk, mn = 4, 4, 8
    long_pl = 36                       # max-length prompt: 9 chunks of 4
    cont = _engine(cfg, mn, max_slots=2, block_size=bs,
                   max_seq_len=long_pl + mn, prefill_chunk=chunk)
    short = _prompts(1, 8, seed=5)[0]
    cont.submit(short)
    cont.step(params)
    short_req = cont.sched.running[0]  # single slot in use so far
    cont.step(params)                  # short request is mid-decode
    long_prompt = _prompts(1, long_pl, seed=6)[0]
    rid_long = cont.submit(long_prompt)
    before = len(short_req.generated)
    outs = cont.step(params)           # admits the long prompt: first chunk
    cont.sched.check_invariants()
    long_req = cont.sched.running[1]
    assert long_req.rid == rid_long and cont._prefilling(long_req)
    while cont._prefilling(long_req):
        outs.extend(cont.step(params))
        cont.sched.check_invariants()
    progressed = len(short_req.generated) - before
    assert cont.max_step_prefill <= chunk
    assert progressed > 0, "decode stalled while the long prompt prefilled"
    outs.extend(cont.drain(params))
    # chunked long-prompt outputs == sync engine outputs
    sync = RolloutEngine(cfg, max_new=mn, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True)
    ref = sync.generate(params, long_prompt[None], jax.random.PRNGKey(5))
    o = next(o for o in outs if o.rid == rid_long)
    n = len(o.gen)
    assert n == ref.lengths[0]
    np.testing.assert_array_equal(np.asarray(o.gen),
                                  ref.tokens[0, long_pl:long_pl + n])


# ---------------------------------------------------------------------------
# partial-rollout resume hits the prefix cache; params change flushes it
# ---------------------------------------------------------------------------

def test_resume_hits_prefix_cache(dense_setup):
    """Budget-suspended requests leave their blocks indexed: the next-run
    resume re-matches every full block of prompt+generated (including
    blocks completed DURING decode) and only prefills the ragged tail."""
    cfg, _, params = dense_setup
    pl, mn, bs = 16, 16, 4
    prompt = _prompts(1, pl, seed=7)[0]
    cont = _engine(cfg, mn, max_slots=2, block_size=bs, max_seq_len=pl + mn)
    cont.submit(prompt, max_new=mn, budget=6)
    _, resum = cont.run_to_budget(params)
    req = resum[0]
    assert cont.shared_prefill_tokens == 0 and cont.prefill_tokens == pl
    cont.submit(req.prompt, generated=req.generated,
                max_new=mn - len(req.generated), budget=6)
    _, resum = cont.run_to_budget(params)
    # stream at resume: 16 prompt + 6 generated = 22 rows; full blocks
    # cover 20 (prompt blocks from admission + one block filled mid-decode)
    assert cont.shared_prefill_tokens == 20
    assert cont.prefill_tokens == pl + 2
    cont.sched.check_invariants()


def test_params_change_flushes_prefix_index(dense_setup):
    """KV cached under old weights must never satisfy a match under new
    weights — a fresh params object flushes the index."""
    cfg, _, params = dense_setup
    pl, mn = 16, 4
    prompt = _prompts(1, pl, seed=8)[0]
    cont = _engine(cfg, mn, max_slots=2, block_size=4, max_seq_len=pl + mn)
    cont.submit(prompt)
    cont.drain(params)
    params2 = jax.tree_util.tree_map(lambda a: a + 0, params)
    cont.submit(prompt)
    cont.drain(params2)
    assert cont.shared_prefill_tokens == 0
    assert cont.prefill_tokens == 2 * pl
    # same object again: the index rebuilt under params2 is matchable
    cont.submit(prompt)
    cont.drain(params2)
    assert cont.shared_prefill_tokens == 12
    cont.sched.check_invariants()


# ---------------------------------------------------------------------------
# randomized admit / evict / resume sweep — invariants after every step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("host_blocks", [0, 24])
def test_randomized_admit_evict_resume_sweep(dense_setup, host_blocks):
    """Duplicate-heavy traffic against a starved pool, submissions arriving
    mid-flight, budget suspends and mid-sequence resumes: refcount/index
    invariants hold after EVERY engine step and every request finishes with
    the sync engine's greedy tokens.  Runs tier-less and with the host KV
    tier attached — the host variant additionally exercises the tiered
    index exclusivity + host slot invariants (``check_invariants`` covers
    both tiers when ``cache.host`` is set)."""
    cfg, _, params = dense_setup
    pl, mn = 12, 10
    rng = np.random.RandomState(11)
    pool = [p for p in _prompts(3, pl, seed=11)]
    sync = RolloutEngine(cfg, max_new=mn, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True)
    ref = sync.generate(params, np.stack(pool), jax.random.PRNGKey(5))
    cont = _engine(cfg, mn, max_slots=3, block_size=4, num_blocks=14,
                   max_seq_len=pl + mn, prefill_chunk=5,
                   host_tier_blocks=host_blocks)

    # phase 1: staggered arrivals, stepped by hand, invariants every step
    arrivals = [int(rng.randint(0, 8)) for _ in range(8)]
    rid2prompt, outs, steps = {}, [], 0
    while arrivals or not cont.sched.idle:
        for t in list(arrivals):
            if t <= steps:
                arrivals.remove(t)
                pi = int(rng.randint(0, 3))
                rid2prompt[cont.submit(pool[pi])] = pi
        outs.extend(cont.step(params))
        cont.sched.check_invariants()
        steps += 1
        assert steps < 500, "engine stopped making progress"
    preempted = sum(o.preemptions for o in outs)

    # phase 2: budgeted rounds with mid-sequence resume
    pending = {}
    for i in range(6):
        pi = int(rng.randint(0, 3))
        rid = cont.submit(pool[pi], max_new=mn, budget=int(rng.randint(2, 6)))
        pending[rid] = pi
    rounds = 0
    while pending:
        finished, resum = cont.run_to_budget(params)
        cont.sched.check_invariants()
        for o in finished:
            rid2prompt[o.rid] = pending.pop(o.rid)
            outs.append(o)
        for req in resum:
            pi = pending.pop(req.rid)
            new_rid = cont.submit(req.prompt, generated=req.generated,
                                  max_new=mn - len(req.generated),
                                  budget=int(rng.randint(2, 6)))
            pending[new_rid] = pi
        rounds += 1
        assert rounds <= 16

    for o in outs:
        pi = rid2prompt[o.rid]
        n = len(o.gen)
        assert n == ref.lengths[pi]
        np.testing.assert_array_equal(np.asarray(o.gen),
                                      ref.tokens[pi, pl:pl + n])
    assert cont.cache.num_free == cont.cache.num_blocks
    assert cont.shared_prefill_tokens > 0, "sweep never hit the prefix cache"
    assert preempted > 0, "pool was never starved"
    if host_blocks:
        assert cont.stats()["swap_out_blocks"] > 0, "sweep never spilled"
        cont.close()


# ---------------------------------------------------------------------------
# MoE + trainer integration
# ---------------------------------------------------------------------------

def test_moe_shared_chunked_matches_sync():
    """MoE chunked prefill groups capacity-based routing over the CHUNK, so
    it matches whole-prompt prefill only while nothing is capacity-dropped
    in either grouping (see ``moe.prefill_paged``) — pin a drop-free
    capacity factor for a sound equality check."""
    cfg = get_smoke_config("mixtral-8x7b").replace(dtype="float32",
                                                   remat=False,
                                                   moe_capacity_factor=4.0)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(1))
    prompt = _prompts(1, 6, seed=6)[0]
    prompts = np.stack([prompt] * 3)
    sync = RolloutEngine(cfg, max_new=8, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True)
    cont = _engine(cfg, 8, max_slots=3, block_size=2, max_seq_len=14,
                   prefill_chunk=3)
    r1 = sync.generate(params, prompts, jax.random.PRNGKey(5))
    for _ in range(3):
        cont.submit(prompt)
    outs = cont.drain(params)
    cont.sched.check_invariants()
    for o in outs:
        n = len(o.gen)
        assert n == r1.lengths[o.rid]
        np.testing.assert_array_equal(np.asarray(o.gen),
                                      r1.tokens[o.rid, 6:6 + n])
    assert cont.shared_prefill_tokens > 0


def test_trainer_group_generation_shares_heads():
    """GRPO with the serving engine: the trainer's N-per-prompt generation
    batch hits the prefix cache for every group member after the first."""
    from repro.configs.base import RLConfig
    from repro.core.trainer import GRPOTrainer
    from repro.data.prompts import PromptDataset, pattern_task

    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=8,
                  rollout_engine="serving", serve_max_slots=4,
                  serve_block_size=4)
    ds = PromptDataset(pattern_task(), max_prompt_len=rl.max_prompt_len,
                       seed=0)
    tr = GRPOTrainer(cfg, rl, ds, num_nodes=2, seed=0)
    tr.iteration(2)
    eng = tr.actor.engine
    assert isinstance(eng, ServingEngine)
    assert eng.shared_prefill_tokens > 0
