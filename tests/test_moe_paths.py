"""MoE implementation paths: the capacity-dispatch einsum and the GMM
dropless path must agree when capacity admits every token."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import moe


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "qwen3-moe-30b",
                                  "llama4-maverick-400b-a17b"])
def test_gmm_path_matches_dispatch(arch, rng):
    cfg = get_smoke_config(arch).replace(
        dtype="float32", remat=False, moe_capacity_factor=8.0)
    p = moe.moe_init(cfg, rng, 1)
    lp = jax.tree.map(lambda v: v[0], p)
    x = jax.random.normal(jax.random.fold_in(rng, 1),
                          (2, 16, cfg.d_model)) * 0.5
    y_disp, aux1 = moe.moe_apply(lp, cfg, x)
    y_gmm, aux2 = moe.moe_apply(lp, cfg.replace(moe_impl="gmm"), x)
    np.testing.assert_allclose(np.asarray(y_disp), np.asarray(y_gmm),
                               rtol=1e-4, atol=1e-5)
    assert float(aux1) == pytest.approx(float(aux2), rel=1e-5)


def test_dispatch_drops_when_capacity_low(rng):
    """With tiny capacity the dispatch path drops tokens (outputs differ
    from the dropless GMM path) — the documented trade-off."""
    cfg = get_smoke_config("mixtral-8x7b").replace(
        dtype="float32", remat=False, moe_capacity_factor=0.25)
    p = moe.moe_init(cfg, rng, 1)
    lp = jax.tree.map(lambda v: v[0], p)
    x = jax.random.normal(jax.random.fold_in(rng, 2),
                          (2, 32, cfg.d_model)) * 0.5
    y_disp, _ = moe.moe_apply(lp, cfg, x)
    y_gmm, _ = moe.moe_apply(lp, cfg.replace(moe_impl="gmm"), x)
    assert np.max(np.abs(np.asarray(y_disp) - np.asarray(y_gmm))) > 1e-3


def test_moe_forward_with_gmm_impl(rng):
    cfg = get_smoke_config("qwen3-moe-30b").replace(
        dtype="float32", remat=False, moe_impl="gmm")
    from repro.models.model import build_model
    m = build_model(cfg)
    params = m.init(cfg, rng)
    batch = {"tokens": jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)}
    logits, aux = m.forward(params, cfg, batch)
    assert not np.any(np.isnan(np.asarray(logits)))


def test_decode_gather_path_matches_dispatch(rng):
    """Small-batch decode uses the weight-gather path (b*k*4 <= E); it must
    match the dispatch-form result."""
    cfg = get_smoke_config("llama4-maverick-400b-a17b").replace(
        dtype="float32", remat=False, moe_capacity_factor=8.0)
    assert cfg.num_experts == 4 and cfg.experts_per_token == 1
    p = moe.moe_init(cfg, rng, 1)
    lp = jax.tree.map(lambda v: v[0], p)
    x = jax.random.normal(jax.random.fold_in(rng, 3),
                          (1, 1, cfg.d_model)) * 0.5   # b*k*4 = 4 <= E
    y_gather = moe.moe_decode_apply(lp, cfg, x)
    y_disp, _ = moe.moe_apply(lp, cfg, x.reshape(1, 1, -1))
    np.testing.assert_allclose(np.asarray(y_gather), np.asarray(y_disp),
                               rtol=1e-4, atol=1e-5)
