"""Pipeline parallelism: GPipe schedule == sequential scan (fwd + grads),
both on a toy stack and on a real transformer layer body."""
import json
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
jax.config.update("jax_default_matmul_precision", "highest")
from repro.launch.mesh import make_mesh
from repro.sharding.pipeline import pipeline_forward, sequential_forward

mesh = make_mesh((4,), ("pipe",))
L, d, B, S = 8, 32, 16, 8
key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (L, d, d)) / np.sqrt(d),
          "b": jax.random.normal(jax.random.fold_in(key, 1), (L, d)) * 0.1}
x = jax.random.normal(jax.random.fold_in(key, 2), (B, S, d))

def layer_fn(lp, h):
    return jnp.tanh(h @ lp["w"] + lp["b"])

ref = sequential_forward(layer_fn, params, x)
out = jax.jit(lambda p, xx: pipeline_forward(
    layer_fn, p, xx, mesh, microbatches=4))(params, x)
err_f = float(np.max(np.abs(np.asarray(out) - np.asarray(ref))))

g1 = jax.jit(jax.grad(lambda p: (pipeline_forward(
    layer_fn, p, x, mesh, microbatches=4) ** 2).sum()))(params)
g2 = jax.grad(lambda p: (sequential_forward(layer_fn, p, x) ** 2).sum())(params)
err_g = max(float(np.max(np.abs(np.asarray(g1[k]) - np.asarray(g2[k]))))
            for k in params)

# real transformer layer body (yi-6b smoke) on a 2-stage pipe
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models import layers as Lx
mesh2 = make_mesh((2,), ("pipe",))
cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
m_params = T.init(cfg, jax.random.PRNGKey(3))
b, s = 4, 16
tokens = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab_size)
xx = Lx.embed_tokens(m_params, cfg, tokens)
cos, sin = T._rope(cfg, T._positions(cfg, b // 2, s))  # per-microbatch tables

def tlayer(lp, h, cos, sin):
    return T._layer_train(cfg, lp, h, cos, sin)

ref2 = sequential_forward(
    lambda lp, h: T._layer_train(cfg, lp, h,
                                 jnp.concatenate([cos, cos]),
                                 jnp.concatenate([sin, sin])),
    m_params["layers"], xx)
out2 = jax.jit(lambda p, h: pipeline_forward(
    tlayer, p, h, mesh2, microbatches=2, consts=(cos, sin)))(
    m_params["layers"], xx)
err_t = float(np.max(np.abs(np.asarray(out2) - np.asarray(ref2))))
print(json.dumps({"err_f": err_f, "err_g": err_g, "err_t": err_t}))
"""


def test_pipeline_matches_sequential():
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["err_f"] < 1e-5, out
    assert out["err_g"] < 1e-4, out
    assert out["err_t"] < 1e-4, out
