"""Tests for the contract analyzer (tools/analyze).

Every pass gets a POSITIVE fixture (a planted violation it must find) and
a NEGATIVE fixture (the compliant variant it must not flag), built as
throwaway source trees with the repo's relative layout.  The final tests
hold the shipped tree itself to the contract: running every pass over the
real repo must produce nothing the shipped baseline does not explain.
"""
import sys
import textwrap
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.analyze import (Project, apply_baseline,  # noqa: E402
                           load_baseline, run_passes)
from tools.analyze.core import PASSES, Finding  # noqa: E402


def make_project(tmp_path, files: dict) -> Project:
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return Project(tmp_path)


def rules(findings) -> list:
    return sorted(f.rule_id for f in findings)


# ---------------------------------------------------------------------------
# determinism (DET001 / DET002)
# ---------------------------------------------------------------------------

def test_determinism_flags_set_iteration_and_wall_clock(tmp_path):
    project = make_project(tmp_path, {"src/repro/serve/mod.py": """\
        import time

        def order_leak():
            s = {1, 2, 3}
            out = []
            for x in s:              # DET001: hash-order iteration
                out.append(x)
            listed = [x for x in s]  # DET001: order-sensitive comprehension
            return out, listed

        def stamp():
            return time.time()       # DET002: wall clock
        """})
    found = run_passes(project, ["determinism"])
    assert rules(found) == ["DET001", "DET001", "DET002"]


def test_determinism_accepts_sorted_reducers_and_monotonic(tmp_path):
    project = make_project(tmp_path, {"src/repro/core/mod.py": """\
        import time

        def ordered():
            s = {1, 2, 3}
            total = sum(x for x in s)      # order-free reducer
            n = len(s)
            out = [x for x in sorted(s)]   # sorted() launders the order
            for x in sorted(s):
                total += x
            return total, n, out

        def clock():
            return time.perf_counter()     # monotonic: allowed
        """})
    assert run_passes(project, ["determinism"]) == []


def test_determinism_scope_excludes_other_packages(tmp_path):
    project = make_project(tmp_path, {"src/repro/launch/mod.py": """\
        import time

        def stamp():
            return time.time()
        """})
    assert run_passes(project, ["determinism"]) == []


# ---------------------------------------------------------------------------
# locks (LOCK001 / LOCK002)
# ---------------------------------------------------------------------------

def test_locks_flags_unguarded_access_and_dead_lock(tmp_path):
    project = make_project(tmp_path, {"src/repro/serve/mod.py": """\
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self._items = []  # guarded-by: _lock

            def add(self, x):
                with self._lock:
                    self._items.append(x)

            def peek(self):
                return self._items[-1]     # LOCK001: no lock held

        class Dead:
            def __init__(self):
                self._mu = threading.Lock()
                self._n = 0  # guarded-by: _mu — but nothing acquires _mu
        """})
    found = run_passes(project, ["locks"])
    assert rules(found) == ["LOCK001", "LOCK002"]
    lock1 = next(f for f in found if f.rule_id == "LOCK001")
    assert "_items" in lock1.message


def test_locks_accepts_guarded_confined_and_requires_lock(tmp_path):
    project = make_project(tmp_path, {"src/repro/serve/mod.py": """\
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0  # guarded-by: _lock

            def bump(self):
                with self._lock:
                    self._n += 1

            def replay(self):  # thread-confined: test-only single thread
                return self._n

            def _bump_locked(self):  # requires-lock: _lock
                self._n += 1
        """})
    assert run_passes(project, ["locks"]) == []


def test_locks_supports_dotted_locks_of_member_objects(tmp_path):
    project = make_project(tmp_path, {"src/repro/serve/mod.py": """\
        class Tier:
            def __init__(self, swap):
                self.swap = swap
                self._inflight = {}  # guarded-by: swap._cond

            def busy(self):
                with self.swap._cond:
                    return bool(self._inflight)

            def leak(self):
                return len(self._inflight)   # LOCK001
        """})
    found = run_passes(project, ["locks"])
    assert rules(found) == ["LOCK001"]
    assert found[0].line == 11


# ---------------------------------------------------------------------------
# tracer-overhead (TRC001)
# ---------------------------------------------------------------------------

def test_overhead_flags_unguarded_allocation_in_hot_module(tmp_path):
    project = make_project(tmp_path, {"src/repro/serve/engine.py": """\
        class Engine:
            def __init__(self, tracer):
                self.tracer = tracer

            def step(self, n):
                self.tracer.instant("serve.step", args={"n": n})  # TRC001
                with self.tracer.span(f"serve.run.{n}"):          # TRC001
                    pass
        """})
    found = run_passes(project, ["tracer-overhead"])
    assert rules(found) == ["TRC001", "TRC001"]


def test_overhead_accepts_guard_idioms_and_constant_args(tmp_path):
    project = make_project(tmp_path, {"src/repro/serve/engine.py": """\
        NULL_SPAN = object()

        class Engine:
            def __init__(self, tracer):
                self.tracer = tracer

            def a_constant_only(self):
                self.tracer.instant("serve.fixed")    # allocates nothing

            def b_if_guard(self, n):
                tr = self.tracer
                if tr.enabled:
                    tr.instant("serve.step", args={"n": n})

            def c_early_return(self, n):
                tr = self.tracer
                if not tr.enabled:
                    return self.work(n)
                with tr.span("serve.step", args={"n": n}):
                    return self.work(n)

            def d_null_span(self, n):
                tr = self.tracer
                span = (tr.span("serve.io", args={"n": n})
                        if tr.enabled else NULL_SPAN)
                with span:
                    return self.work(n)

            def work(self, n):
                return n
        """})
    assert run_passes(project, ["tracer-overhead"]) == []


def test_overhead_scope_is_hot_modules_only(tmp_path):
    project = make_project(tmp_path, {"src/repro/serve/cold.py": """\
        class Report:
            def __init__(self, tracer):
                self.tracer = tracer

            def emit(self, n):
                self.tracer.instant("serve.report", args={"n": n})
        """})
    assert run_passes(project, ["tracer-overhead"]) == []


# ---------------------------------------------------------------------------
# kernel-shapes (KRN001..KRN004)
# ---------------------------------------------------------------------------

def test_kernels_flags_arity_rank_and_unbounded_dims(tmp_path):
    project = make_project(tmp_path, {"src/repro/kernels/bad.py": """\
        def launch(x, n):
            return pl.pallas_call(
                kern,
                grid=(4, 4),
                in_specs=[
                    pl.BlockSpec((128, 128), lambda i: (i, 0)),       # KRN001
                    pl.BlockSpec((128, 128), lambda i, j: (i,)),      # KRN001
                    pl.BlockSpec((n, 128), lambda i, j: (i, j)),      # KRN004
                ],
                out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
            )(x)
        """})
    found = run_passes(project, ["kernel-shapes"])
    assert rules(found) == ["KRN001", "KRN001", "KRN004"]


def test_kernels_flags_unenforced_docstring_assumption(tmp_path):
    project = make_project(tmp_path, {"src/repro/kernels/bad.py": """\
        def launch(x):
            \"\"\"x rows must be a multiple of 128.\"\"\"
            return pl.pallas_call(
                kern,
                grid=(4,),
                in_specs=[pl.BlockSpec((128,), lambda i: (i,))],
                out_specs=pl.BlockSpec((128,), lambda i: (i,)),
            )(x)
        """})
    found = run_passes(project, ["kernel-shapes"])
    assert rules(found) == ["KRN002"]


def test_kernels_flags_vmem_budget_overflow(tmp_path):
    project = make_project(tmp_path, {"src/repro/kernels/bad.py": """\
        def launch(x, block=4096):
            return pl.pallas_call(
                kern,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((block, 4096), lambda i, j: (i, j))],
                out_specs=pl.BlockSpec((block, 4096), lambda i, j: (i, j)),
            )(x)
        """})
    found = run_passes(project, ["kernel-shapes"])       # 2 x 64 MiB blocks
    assert rules(found) == ["KRN003"]


def test_kernels_accepts_bounded_enforced_kernel(tmp_path):
    project = make_project(tmp_path, {"src/repro/kernels/good.py": """\
        VMEM_BOUNDS = {"d": 1024}

        def launch(x, d, block=128):
            \"\"\"rows must be a multiple of block.\"\"\"
            assert x.shape[0] % block == 0
            return pl.pallas_call(
                kern,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((block, d), lambda i, j: (i, j))],
                out_specs=pl.BlockSpec((block, d), lambda i, j: (i, j)),
            )(x)
        """})
    assert run_passes(project, ["kernel-shapes"]) == []


def test_kernels_resolves_min_shrink_pattern(tmp_path):
    project = make_project(tmp_path, {"src/repro/kernels/good.py": """\
        def launch(x, rows, block=256):
            block = min(block, rows)     # bound survives self-reference
            return pl.pallas_call(
                kern,
                grid=(4,),
                in_specs=[pl.BlockSpec((block, 512), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((block, 512), lambda i: (i, 0)),
            )(x)
        """})
    assert run_passes(project, ["kernel-shapes"]) == []


# ---------------------------------------------------------------------------
# drift (DRF001 / DRF002)
# ---------------------------------------------------------------------------

_DRIFT_BASE = """\
    from dataclasses import dataclass

    @dataclass
    class RLConfig:
        lr: float = 1e-5
        mystery_knob: int = 3
    """


def test_drift_flags_unreachable_knob_and_uncataloged_name(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/configs/base.py": _DRIFT_BASE,
        "src/repro/launch/train.py": "def main(lr):\n    return lr\n",
        "docs/observability.md": "| `serve.steps` | counter |\n",
        "src/repro/serve/mod.py": """\
            def tick(metrics):
                metrics.inc("serve.steps")
                metrics.inc("serve.mystery_counter")   # DRF002
            """,
    })
    found = run_passes(project, ["drift"])
    assert rules(found) == ["DRF001", "DRF002"]
    drf1 = next(f for f in found if f.rule_id == "DRF001")
    assert "mystery_knob" in drf1.message
    drf2 = next(f for f in found if f.rule_id == "DRF002")
    assert "serve.mystery_counter" in drf2.message


def test_drift_accepts_documented_knobs_and_cataloged_names(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/configs/base.py": _DRIFT_BASE,
        "src/repro/launch/train.py": "def main(lr):\n    return lr\n",
        "docs/knobs.md": "`mystery_knob` controls the mystery.\n",
        "docs/observability.md": "| `serve.steps` | counter |\n",
        "src/repro/serve/mod.py": """\
            def tick(metrics, fast):
                metrics.inc("serve.steps" if fast else "serve.steps")
            """,
    })
    assert run_passes(project, ["drift"]) == []


# ---------------------------------------------------------------------------
# faults (FLT001)
# ---------------------------------------------------------------------------

def test_faults_flags_uncataloged_site(tmp_path):
    project = make_project(tmp_path, {
        "docs/resilience.md": "| `swap.out` | spill |\n",
        "src/repro/serve/mod.py": """\
            def spill(self):
                if self.faults is not None:
                    self.faults.check("swap.out")
                    self.faults.check("swap.mystery")   # FLT001
            """,
    })
    found = run_passes(project, ["faults"])
    assert rules(found) == ["FLT001"]
    assert "swap.mystery" in found[0].message


def test_faults_accepts_cataloged_and_computed_sites(tmp_path):
    project = make_project(tmp_path, {
        "docs/resilience.md": "| `dock.put` | row landing |\n",
        "src/repro/core/mod.py": """\
            def put(self, node):
                self.faults.check("dock.put")
                # computed family: documented as stage.<node>, not literal
                self.faults.check("stage." + node.name)
                faults = self.faults
                faults.check("dock.put" if node.stream else "dock.put")
                # .check on a non-faults receiver is not a fault site
                self.dock.check("anything")
            """,
    })
    assert run_passes(project, ["faults"]) == []


# ---------------------------------------------------------------------------
# baseline machinery
# ---------------------------------------------------------------------------

def test_baseline_suppresses_by_substring_and_reports_stale():
    findings = [Finding("src/a.py", 10, "LOCK001", "`self._x` unguarded"),
                Finding("src/a.py", 20, "LOCK001", "`self._y` unguarded")]
    entries = [
        {"rule": "LOCK001", "file": "src/a.py", "contains": "`self._x`",
         "reason": "benign double-checked read"},
        {"rule": "LOCK001", "file": "src/gone.py", "contains": "anything",
         "reason": "stale"},
    ]
    kept, suppressed, stale = apply_baseline(findings, entries)
    assert [f.line for f in kept] == [20]
    assert [f.line for f in suppressed] == [10]
    assert [e["file"] for e in stale] == ["src/gone.py"]


def test_baseline_requires_reason(tmp_path):
    bad = tmp_path / "baseline.json"
    bad.write_text('[{"rule": "X", "file": "y", "contains": "z"}]')
    try:
        load_baseline(bad)
    except ValueError as e:
        assert "reason" in str(e)
    else:
        raise AssertionError("missing-reason baseline entry accepted")


# ---------------------------------------------------------------------------
# the shipped tree honors its own contracts
# ---------------------------------------------------------------------------

def test_all_six_passes_are_registered():
    assert sorted(PASSES) == ["determinism", "drift", "faults",
                              "kernel-shapes", "locks", "tracer-overhead"]
    owned = sorted(r for p in PASSES.values() for r in p.rule_ids)
    assert owned == ["DET001", "DET002", "DRF001", "DRF002", "FLT001",
                     "KRN001", "KRN002", "KRN003", "KRN004", "LOCK001",
                     "LOCK002", "TRC001"]


def test_shipped_tree_clean_under_shipped_baseline():
    project = Project(REPO_ROOT)
    findings = run_passes(project)
    entries = load_baseline(REPO_ROOT / "tools" / "analyze" / "baseline.json")
    kept, _suppressed, stale = apply_baseline(findings, entries)
    assert kept == [], "unsuppressed findings:\n" + "\n".join(
        f.render() for f in kept)
    assert stale == [], f"stale baseline entries: {stale}"


def test_cli_exits_nonzero_on_findings(tmp_path):
    from tools.analyze.__main__ import main
    make_project(tmp_path, {"src/repro/serve/mod.py": """\
        def order_leak():
            s = {1, 2}
            for x in s:
                print(x)
        """})
    assert main(["--root", str(tmp_path), "--no-baseline"]) == 1
    assert main(["--root", str(tmp_path), "--rule", "LOCK"]) == 0
    assert main(["--list-rules"]) == 0
