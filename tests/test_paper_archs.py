"""The paper's own evaluation models (qwen2.5-7b/32b, qwen3-moe-30b) as
smoke configs — forward + decode consistency, same bar as the assigned ten."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER_ARCHS, get_config, get_smoke_config
from repro.models.model import build_model


@pytest.mark.parametrize("arch", PAPER_ARCHS)
def test_paper_arch_forward_and_decode(arch, rng):
    cfg = get_smoke_config(arch).replace(
        dtype="float32", remat=False, moe_capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init(cfg, rng)
    b, s, pl = 2, 16, 8
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size)}
    logits, aux = m.forward(params, cfg, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits)))
    cache = m.init_cache(cfg, b, s)
    pb = dict(batch, tokens=batch["tokens"][:, :pl])
    lg, cache = m.prefill(params, cfg, pb, cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, pl - 1]),
                               rtol=2e-4, atol=2e-4)
    lg, cache = m.decode(params, cfg, cache, batch["tokens"][:, pl:pl + 1],
                         jnp.int32(pl))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, pl]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", PAPER_ARCHS)
def test_paper_arch_full_config_cites(arch):
    cfg = get_config(arch)
    assert cfg.num_layers >= 28
    assert cfg.vocab_size > 100_000
