"""Fault-injection & recovery layer (repro.resilience + the runtime hooks).

The contracts under test:

  * fault plans — ``FaultPlan`` is a deterministic, replayable schedule:
    the spec string round-trips through parse/describe, ``check`` fires at
    exactly the scheduled (site, hit) pairs, and randomized plans are a
    pure function of their seed;
  * stage retry — transient stage/dock failures are retried with capped
    deterministic backoff and the recovered run is BIT-IDENTICAL to the
    fault-free run (retry re-runs the whole stage from the fetch);
  * quarantine — a stage that exhausts its retry budget drops exactly its
    dispatch's samples; downstream barriers shrink so survivors still flow;
  * swap-failure degradation — a swap-worker failure flips the engine to
    recompute-preemption mode (tier detached, garbage swap-in blocks
    preempted) instead of crashing, and greedy gen AND gen_logp stay
    bitwise identical to a tier-off run;
  * close() hygiene — a pending worker failure surfaces from ``close()``
    (never silently joined away) and a join timeout is counted;
  * checkpoint/resume — ``save_train_state``/``load_train_state`` replay
    the remaining iterations bit-exactly, including partial-rollout
    carryover, dock contents and every RNG cursor.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RLConfig
from repro.core.graph import GraphExecutor, RLGraph, StageNode
from repro.core.transfer_dock import TransferDock
from repro.data.prompts import PromptDataset, pattern_task
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.resilience import (FatalFault, FaultPlan, FaultSpec, RetryPolicy,
                              TransientError, TransientFault, call_with_retry)
from repro.serve.engine import ServingEngine
from repro.serve.host_tier import HostKVTier, SwapWorkerError

TOK = ByteTokenizer()


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    return cfg, m, params


# ---------------------------------------------------------------------------
# FaultPlan: deterministic, replayable schedules
# ---------------------------------------------------------------------------

def test_fault_plan_parse_describe_roundtrip():
    spec = "dock.put@3,stage.reward@1,stage.reward@4:fatal,swap.out@2"
    plan = FaultPlan.parse(spec)
    assert plan.describe() == spec
    assert FaultPlan.parse(plan.describe()).describe() == spec


def test_fault_plan_rejects_bad_specs():
    with pytest.raises(ValueError):
        FaultPlan.parse("no-at-sign")
    with pytest.raises(ValueError):
        FaultPlan.parse("site@0")          # hits are 1-based
    with pytest.raises(ValueError):
        FaultSpec("site", 1, "weird")


def test_fault_plan_fires_exactly_at_scheduled_hits():
    plan = FaultPlan.parse("a@2,a@4:fatal,b@1")
    plan.check("a")                        # hit 1: clean
    with pytest.raises(TransientFault) as ti:
        plan.check("a")                    # hit 2: scheduled transient
    assert isinstance(ti.value, TransientError)
    assert (ti.value.site, ti.value.hit) == ("a", 2)
    plan.check("a")                        # hit 3: clean
    with pytest.raises(FatalFault):
        plan.check("a")                    # hit 4: scheduled fatal
    with pytest.raises(TransientFault):
        plan.check("b")
    plan.check("c")                        # unscheduled site never fires
    assert [s.describe() for s in plan.fired] == ["a@2", "a@4:fatal", "b@1"]
    assert plan.counts() == {"a": 4, "b": 1, "c": 1}
    plan.reset()
    assert plan.counts() == {} and plan.fired == []
    plan.check("a")
    with pytest.raises(TransientFault):
        plan.check("a")                    # same schedule replays after reset


def test_random_plan_is_a_pure_function_of_seed():
    sites = ["swap.out", "swap.in", "dock.put"]
    a = FaultPlan.random_plan(3, sites, 5)
    b = FaultPlan.random_plan(3, sites, 5)
    c = FaultPlan.random_plan(4, sites, 5)
    assert a.describe() == b.describe()
    assert a.describe() != c.describe()
    assert len(a.describe().split(",")) == 5


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------

def test_backoff_is_deterministic_and_capped():
    pol = RetryPolicy(max_retries=8, backoff_base_s=0.001, backoff_cap_s=0.05)
    delays = [pol.backoff(i) for i in range(8)]
    assert delays == [pol.backoff(i) for i in range(8)]   # pure
    assert delays[0] == 0.001 and max(delays) == 0.05
    assert all(d2 >= d1 for d1, d2 in zip(delays, delays[1:]))


def test_call_with_retry_recovers_and_reports():
    calls, notes = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientFault("x", len(calls))
        return "ok"

    pol = RetryPolicy(max_retries=3, backoff_base_s=0.0, backoff_cap_s=0.0)
    got = call_with_retry(flaky, pol,
                          on_retry=lambda a, e: notes.append((a, e.site)))
    assert got == "ok" and len(calls) == 3
    assert notes == [(0, "x"), (1, "x")]


def test_call_with_retry_exhausts_budget():
    def always():
        raise TransientFault("y", 1)

    pol = RetryPolicy(max_retries=2, backoff_base_s=0.0, backoff_cap_s=0.0)
    with pytest.raises(TransientFault):
        call_with_retry(always, pol)
    with pytest.raises(FatalFault):        # non-transient: never retried
        call_with_retry(lambda: (_ for _ in ()).throw(FatalFault("z", 1)),
                        pol)


# ---------------------------------------------------------------------------
# executor: retry bit-identity + quarantine (pure-numpy graph, no model)
# ---------------------------------------------------------------------------

def _tiny_graph(gate=None):
    def double(ctx, io):
        return {"x": io.ins["prompt"] * 2}

    def total(ctx, io):
        ctx.sums[tuple(io.idxs)] = io.ins["x"].sum()
        return None

    return RLGraph("tiny", [
        StageNode("double", 0, inputs=("prompt",), outputs=("x",),
                  fn=double, stream=True, gate=gate),
        StageNode("total", 0, inputs=("x",), outputs=(), fn=total),
    ])


class _Ctx:
    """Minimal executor ctx: the tiny graph has no layout edges, so the
    resharder is never touched."""
    resharder = None
    rl = RLConfig(stage_fusion=False)

    def __init__(self):
        self.sums = {}


def _run_tiny(faults=None, gate=None, node_retries=None):
    graph = _tiny_graph(gate)
    if node_retries is not None:
        graph.nodes[0].max_retries = node_retries
    dock = TransferDock(1, graph.states(), faults=faults)
    ex = GraphExecutor(dock, _Ctx.rl, faults=faults,
                       retry=RetryPolicy(max_retries=2, backoff_base_s=0.0,
                                         backoff_cap_s=0.0))
    ctx = _Ctx()
    dock.put("prompt", list(range(4)), np.arange(4 * 3).reshape(4, 3),
             src_node=0)
    run = ex.run(graph, ctx, expected=4)
    return run, ex, dock, ctx


def test_executor_retries_transient_stage_faults_bit_identically():
    _, _, base_dock, base_ctx = _run_tiny()
    plan = FaultPlan.parse("stage.double@1,dock.put@2")
    run, ex, dock, ctx = _run_tiny(faults=plan)
    assert [s.describe() for s in plan.fired] == ["stage.double@1",
                                                 "dock.put@2"]
    assert run.retries == {"double": 2}    # one stage retry + one put retry
    assert ex.metrics.value("graph.retry") == 2
    assert not run.quarantined
    # the recovered run's dock rows and downstream results are bit-identical
    for idx in range(4):
        np.testing.assert_array_equal(dock.get("total", "x", [idx], 0),
                                      base_dock.get("total", "x", [idx], 0))
    assert ctx.sums == base_ctx.sums


def test_executor_quarantines_after_budget_and_shrinks_barriers():
    # gate the stream node so its FIRST dispatch covers exactly {0, 1}; all
    # three attempts of that dispatch fault -> quarantine; the second
    # dispatch {2, 3} is clean and the downstream barrier (expected=4)
    # shrinks to the 2 survivors instead of waiting forever
    state = {"first": True}

    def gate(ctx, idxs):
        if state["first"] and len(idxs) >= 2:
            state["first"] = False
            return sorted(idxs)[:2]
        return idxs

    plan = FaultPlan.parse("stage.double@1,stage.double@2,stage.double@3")
    run, ex, dock, ctx = _run_tiny(faults=plan, gate=gate)
    assert run.quarantined == {"double": [0, 1]}
    assert run.quarantined_idxs == {0, 1}
    assert ex.metrics.value("graph.quarantined") == 2
    assert list(ctx.sums) == [(2, 3)], "barrier must fire on the survivors"
    arr = np.arange(12).reshape(4, 3)
    assert ctx.sums[(2, 3)] == (arr[2:] * 2).sum()


def test_per_node_retry_budget_overrides_executor_default():
    # node budget 0: the first transient fault quarantines immediately even
    # though the executor default would have retried it
    plan = FaultPlan.parse("stage.double@1")
    run, ex, _, _ = _run_tiny(faults=plan, node_retries=0)
    assert run.retries == {}
    assert run.quarantined == {"double": [0, 1, 2, 3]}


# ---------------------------------------------------------------------------
# swap engine close(): failures surface, timeouts are counted
# ---------------------------------------------------------------------------

def test_close_surfaces_pending_worker_failure(dense_setup):
    """Regression: close() used to drain/join without re-checking the
    worker's error slot — a failure in the final jobs vanished silently."""
    cfg, _, _ = dense_setup
    from repro.serve.paged_cache import prefix_key
    plan = FaultPlan.parse("swap.out@1")
    tier = HostKVTier(cfg, num_blocks=2, block_size=4, faults=plan)
    shp = (cfg.num_layers, 4, cfg.num_kv_heads, cfg.head_dim)
    k = v = np.zeros(shp, np.float32)
    tier.put(prefix_key(b"", np.arange(4)), k, v)
    with pytest.raises(SwapWorkerError, match="KV swap worker failed"):
        tier.close()
    assert plan.fired, "the injected spill fault never fired"


def test_close_join_timeout_is_counted(dense_setup):
    cfg, _, _ = dense_setup
    tier = HostKVTier(cfg, num_blocks=2, block_size=4)
    stuck = threading.Thread(target=lambda: time.sleep(2.0), daemon=True)
    stuck.start()
    tier.swap._thread = stuck              # simulate a wedged worker
    tier.swap.close(timeout=0.05)
    assert tier.metrics.value("serve.swap.close_timeout") == 1
    assert tier.swap._thread is None


def test_drain_handles_externally_killed_worker(dense_setup):
    cfg, _, _ = dense_setup
    tier = HostKVTier(cfg, num_blocks=2, block_size=4)
    with tier.swap._cond:
        tier.swap._pending = 1             # job lost: no worker ever ran it
    with pytest.raises(SwapWorkerError):
        tier.swap.drain()


# ---------------------------------------------------------------------------
# swap-failure degradation: bitwise-identical fallback to recompute
# ---------------------------------------------------------------------------

def _prompts(b, pl, seed=0):
    return np.random.RandomState(seed).randint(0, 250, (b, pl)).astype(np.int32)


def _sweep(cfg, params, host_blocks, faults=None):
    """The host-tier bit-identity workload (tests/test_host_tier.py),
    plus an optional fault plan and per-step invariant checks that stay
    valid across mid-run degradation."""
    pl, mn = 12, 10
    pool = [p for p in _prompts(3, pl, seed=21)]
    eng = ServingEngine(cfg, max_new=mn, eos_id=TOK.eos_id, pad_id=TOK.pad_id,
                        greedy=True, max_slots=3, block_size=4, num_blocks=14,
                        max_seq_len=pl + mn, host_tier_blocks=host_blocks,
                        faults=faults)

    def invariants():
        # invariants hold after every step even while the tier degrades
        # mid-run; the one tolerated wrinkle is a worker failure that fired
        # AFTER this step's barrier — the tier is still attached, so
        # check_consistent's drain re-raises it (the engine's next step
        # resolves it into degradation)
        try:
            eng.sched.check_invariants()
        except SwapWorkerError:
            assert faults is not None

    arrivals = [(0, 0), (0, 1), (1, 2), (2, 0), (3, 1), (3, 0), (5, 2),
                (7, 1)]
    outs, steps = [], 0
    while arrivals or not eng.sched.idle:
        while arrivals and arrivals[0][0] <= steps:
            eng.submit(pool[arrivals.pop(0)[1]])
        outs.extend(eng.step(params))
        invariants()
        steps += 1
        assert steps < 500
    budgets = [2, 5, 3, 4]
    pending = set()
    for i, bud in enumerate(budgets):
        pending.add(eng.submit(pool[i % 3], max_new=mn, budget=bud))
    rounds = 0
    while pending:
        finished, resum = eng.run_to_budget(params)
        invariants()
        for o in finished:
            pending.discard(o.rid)
            outs.append(o)
        for req in resum:
            pending.discard(req.rid)
            pending.add(eng.submit(req.prompt, generated=req.generated,
                                   max_new=mn - len(req.generated),
                                   budget=budgets[rounds % 4]))
        rounds += 1
        assert rounds <= 16
    stats = eng.stats()
    degraded = eng._host_degraded
    eng.close()
    return outs, stats, degraded


def _assert_bitwise_equal(a, b):
    da = {o.rid: o for o in a}
    db = {o.rid: o for o in b}
    assert sorted(da) == sorted(db)
    for rid in da:
        np.testing.assert_array_equal(np.asarray(da[rid].gen),
                                      np.asarray(db[rid].gen))
        np.testing.assert_array_equal(da[rid].gen_logp, db[rid].gen_logp)


def test_spill_failure_degrades_to_recompute_bit_identically(dense_setup):
    """First spill job dies in the worker -> the engine drops the tier and
    finishes the whole preemption-heavy workload on recompute, bitwise
    equal to a tier-off run."""
    cfg, _, params = dense_setup
    off, off_stats, _ = _sweep(cfg, params, 0)
    plan = FaultPlan.parse("swap.out@1")
    on, on_stats, degraded = _sweep(cfg, params, 24, faults=plan)
    assert plan.fired and degraded
    assert on_stats["swap_degraded"] == 1
    assert off_stats["preemptions"] > 0, "pool was never starved"
    _assert_bitwise_equal(off, on)


def test_swapin_failure_preempts_victims_and_degrades(dense_setup):
    """A swap-in upload dies AFTER its target block was registered: the
    engine must preempt the owner (garbage rows are never read) and still
    produce bitwise tier-off outputs."""
    cfg, _, params = dense_setup
    off, _, _ = _sweep(cfg, params, 0)
    plan = FaultPlan.parse("swap.in@2")
    on, on_stats, degraded = _sweep(cfg, params, 24, faults=plan)
    assert plan.fired and degraded
    assert on_stats["swap_degraded"] == 1
    assert on_stats["swap_in_blocks"] >= 2, "workload never reached the fault"
    _assert_bitwise_equal(off, on)


def test_randomized_fault_sweep_every_site(dense_setup):
    """Satellite sweep: seeded random plans over BOTH swap sites, against
    the preemption-heavy workload; whatever fires, invariants hold every
    step and the final outputs are bitwise tier-off."""
    cfg, _, params = dense_setup
    off, _, _ = _sweep(cfg, params, 0)
    fired_sites = set()
    for seed in range(3):
        plan = FaultPlan.random_plan(seed, ["swap.out", "swap.in"], 3,
                                     max_hit=6)
        on, on_stats, degraded = _sweep(cfg, params, 24, faults=plan)
        _assert_bitwise_equal(off, on)
        if plan.fired:
            assert degraded and on_stats["swap_degraded"] == 1
        fired_sites.update(s.site for s in plan.fired)
    assert fired_sites, "no random plan ever fired — sweep is vacuous"


# ---------------------------------------------------------------------------
# trainer-level chaos + checkpoint/resume
# ---------------------------------------------------------------------------

def _trainer(faults=None, seed=3, partial=False, starve_blocks=0, **rl_over):
    from repro.core.trainer import GRPOTrainer

    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    rl_kw = dict(num_generations=2, max_prompt_len=12, max_response_len=8,
                 rollout_engine="serving", serve_max_slots=4,
                 serve_block_size=4, partial_rollout=partial)
    rl_kw.update(rl_over)
    rl = RLConfig(**rl_kw)
    ds = PromptDataset(pattern_task(), max_prompt_len=rl.max_prompt_len,
                       seed=seed)
    if partial:
        from repro.core.partial import PartialRolloutTrainer
        tr = PartialRolloutTrainer(cfg, rl, ds, budget=5, num_nodes=2,
                                   seed=seed, faults=faults)
    else:
        tr = GRPOTrainer(cfg, rl, ds, num_nodes=2, seed=seed, faults=faults)
    if starve_blocks:
        # shrink the device pool below the workload's live demand so the
        # run preempts (and, with a host tier, spills) — the chaos tests
        # need real swap traffic, not a comfortably sized pool
        tr.actor.engine._num_blocks_req = starve_blocks
    return tr


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def _assert_trees_equal(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


def test_chaos_trainer_run_bit_identical_to_fault_free():
    """THE acceptance chaos test: swap-worker death plus multiple transient
    stage/dock faults across a 2-iteration serving run — every fault is
    absorbed (retry or degradation) and the final policy weights are
    bitwise identical to the fault-free run."""
    kw = dict(seed=3, serve_host_tier_blocks=12, greedy=True,
              starve_blocks=9)
    base = _trainer(**kw)
    for _ in range(2):
        base.iteration(2)
    assert base.actor.engine.stats()["swap_out_blocks"] > 0, \
        "workload never spilled — the chaos run would fault nothing"
    plan = FaultPlan.parse("swap.out@1,stage.ref_inference@1,"
                           "stage.actor_inference@2,dock.put@2")
    chaos = _trainer(faults=plan, **kw)
    for _ in range(2):
        chaos.iteration(2)
    fired = {s.site for s in plan.fired}
    assert "swap.out" in fired, "swap worker never died"
    assert len([s for s in plan.fired if s.site.startswith("stage.")]) >= 2
    assert chaos.actor.engine._host_degraded
    assert chaos.executor.metrics.value("graph.retry") >= 2
    assert not chaos.last_run.quarantined
    _assert_trees_equal(base.params, chaos.params)
    _assert_trees_equal(base.opt_state, chaos.opt_state)


def test_trainer_quarantine_drops_batch_and_completes():
    """Retry budget exhausted at a barrier stage: the iteration still
    quiesces (no hang), the drop is reported, and the policy is untouched
    because the update stage never saw a full batch."""
    plan = FaultPlan.parse(",".join(f"stage.actor_inference@{h}"
                                    for h in (1, 2, 3)))
    tr = _trainer(faults=plan, rollout_engine="sync")
    before = _leaves(tr.params)
    tr.iteration(2)
    run = tr.last_run
    assert run.quarantined == {"actor_inference": [0, 1, 2, 3]}
    assert run.quarantined_idxs == {0, 1, 2, 3}
    assert tr.executor.metrics.value("graph.quarantined") == 4
    for x, y in zip(before, _leaves(tr.params)):
        np.testing.assert_array_equal(x, y)


def test_fatal_fault_propagates_out_of_iteration():
    plan = FaultPlan.parse("stage.actor_update@1:fatal")
    tr = _trainer(faults=plan, rollout_engine="sync")
    with pytest.raises(FatalFault):
        tr.iteration(2)


def test_checkpoint_resume_grpo_bit_exact(tmp_path):
    from repro.checkpoint import (is_train_state, load_train_state,
                                  save_train_state)
    straight = _trainer(seed=5)
    for _ in range(3):
        straight.iteration(2)

    half = _trainer(seed=5)
    for _ in range(2):
        half.iteration(2)
    path = str(tmp_path / "state.npz")
    save_train_state(path, half, iteration=2)
    assert is_train_state(path)

    resumed = _trainer(seed=5)
    assert load_train_state(path, resumed) == 2
    assert resumed.ref.params is resumed.ref_params, \
        "reference worker must track the restored ref pytree"
    resumed.iteration(2)
    _assert_trees_equal(straight.params, resumed.params)
    _assert_trees_equal(straight.opt_state, resumed.opt_state)
    _assert_trees_equal(straight.ref_params, resumed.ref_params)
    np.testing.assert_array_equal(np.asarray(straight.key),
                                  np.asarray(resumed.key))


def test_checkpoint_resume_partial_rollout_carryover(tmp_path):
    """Partial rollout is the hard case: pending sequences, dock rows and
    the persistent index counter all span iterations and must survive the
    snapshot for the resumed run to replay bit-exactly."""
    from repro.checkpoint import load_train_state, save_train_state
    straight = _trainer(seed=5, partial=True)
    for _ in range(3):
        straight.iteration(2)

    half = _trainer(seed=5, partial=True)
    for _ in range(2):
        half.iteration(2)
    assert half.pending_partials > 0, \
        "budget never suspended anything — the carryover case is vacuous"
    path = str(tmp_path / "pstate.npz")
    save_train_state(path, half, iteration=2)

    resumed = _trainer(seed=5, partial=True)
    assert load_train_state(path, resumed) == 2
    assert sorted(resumed.partials) == sorted(half.partials)
    assert resumed._next_idx == half._next_idx
    resumed.iteration(2)
    _assert_trees_equal(straight.params, resumed.params)
    _assert_trees_equal(straight.opt_state, resumed.opt_state)
    assert sorted(resumed.partials) == sorted(straight.partials)
    for i in straight.partials:
        assert resumed.partials[i].generated == straight.partials[i].generated


def test_legacy_params_checkpoint_still_detected(tmp_path):
    from repro.checkpoint import is_train_state, save_pytree
    path = str(tmp_path / "legacy.npz")
    save_pytree(path, {"w": np.zeros(3)}, step=1)
    assert not is_train_state(path)
    assert not is_train_state(str(tmp_path / "missing.npz"))
