"""Distributed correctness: forward/train-step on a multi-device mesh must
match the single-device result — this validates every sharding rule and
with_sharding_constraint added by the perf work.  Runs in a subprocess (the
8-device XLA flag must precede jax init)."""
import json
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax, numpy as np
import jax.numpy as jnp
jax.config.update("jax_default_matmul_precision", "highest")
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.configs.base import RLConfig
from repro.launch.mesh import make_mesh
from repro.core import grpo
from repro.models.model import build_model
from repro.optim import adamw_init
from repro.sharding import param_specs, batch_partition

out = {}
for arch in ("yi-6b", "mixtral-8x7b", "mamba2-1.3b"):
    cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                          cfg.vocab_size)}
    # single device
    logits1, _ = jax.jit(lambda p, b: m.forward(p, cfg, b))(params, batch)

    # 8-device mesh (2 data x 4 model), full sharding rules + constraints
    mesh = make_mesh((2, 4), ("data", "model"))
    specs = param_specs(cfg, params, mesh, stage="train")
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    pd = jax.device_put(params, shardings)
    bd = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    with mesh:
        logits8, _ = jax.jit(lambda p, b: m.forward(p, cfg, b))(pd, bd)
    err = float(np.max(np.abs(np.asarray(logits1) - np.asarray(logits8))))
    scale = float(np.max(np.abs(np.asarray(logits1))))
    out[arch] = {"err": err, "scale": scale}
print(json.dumps(out))
"""


def test_mesh_forward_matches_single_device():
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for arch, r in out.items():
        assert r["err"] <= 1e-3 * max(r["scale"], 1.0), (arch, r)
