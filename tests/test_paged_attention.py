"""Paged decode attention: Pallas kernel vs jnp ref vs dense-gather oracle.

Three implementations, one contract:

  * ``kernels/ref.paged_decode_attention`` (CPU path) must be BITWISE equal
    to ``ops.decode_attention`` over the dense-gathered view — the serving
    engine's bit-compatibility with ``RolloutEngine`` rides on it.
  * the Pallas kernel (interpret mode here) is online-softmax — numerically
    close, and greedy decode lands on identical tokens (subprocess test).
  * the jitted serving step must materialize NO dense (n, S, MB*bs, kv, hd)
    cache view: checked against the optimized HLO and the compiled step's
    temp-buffer footprint as ``max_blocks_per_seq`` grows.
"""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.rollout import RolloutEngine
from repro.data.tokenizer import ByteTokenizer
from repro.kernels import ops, ref
from repro.kernels.paged_attention import paged_decode_attention as pallas_pda
from repro.models.model import build_model
from repro.serve.engine import ServingEngine, prefill_bucket
from repro.serve.paged_cache import gather_pool_ref

TOK = ByteTokenizer()


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    return cfg, m, params


def _rand_case(seed, s=4, kv=2, g=4, hd=32, bs=4, mb=5, nblk=24):
    """Random pool/tables/pos + the dense-gathered oracle inputs."""
    rng = np.random.RandomState(seed)
    h = kv * g
    nblk = max(nblk, s * mb)
    r = (nblk + 1) * bs
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (s, 1, h, hd), jnp.float32)
    pool_k = jax.random.normal(ks[1], (r, kv, hd), jnp.float32)
    pool_v = jax.random.normal(ks[2], (r, kv, hd), jnp.float32)
    k_new = jax.random.normal(ks[3], (s, kv, hd), jnp.float32)
    v_new = jax.random.normal(ks[4], (s, kv, hd), jnp.float32)
    # each slot owns disjoint random blocks (like a real allocation)
    perm = rng.permutation(nblk)[:s * mb].reshape(s, mb)
    tables = jnp.asarray(perm, jnp.int32)
    # ragged: corner positions (empty slot, full slot) + random interior
    pos = np.array([0, mb * bs - 1] + list(rng.randint(0, mb * bs, s - 2)),
                   np.int32)[:s]
    return q, k_new, v_new, pool_k, pool_v, tables, jnp.asarray(pos), bs


def _oracle(q, k_new, v_new, pool_k, pool_v, tables, pos, bs):
    """gather_kv + insert-at-pos + dense decode_attention (the old path)."""
    kc = gather_pool_ref(pool_k[None], tables, bs)[0]
    vc = gather_pool_ref(pool_v[None], tables, bs)[0]
    rows = jnp.arange(q.shape[0])
    kc = kc.at[rows, pos].set(k_new)
    vc = vc.at[rows, pos].set(v_new)
    cap = tables.shape[1] * bs
    valid = jnp.arange(cap)[None, :] <= pos[:, None]
    return ops.decode_attention(q, kc, vc, valid)


def test_ref_bitwise_matches_dense_oracle():
    args = _rand_case(0)
    want = np.asarray(jax.jit(_oracle, static_argnums=(7,))(*args))
    got = np.asarray(jax.jit(
        ref.paged_decode_attention,
        static_argnames=("block_size",))(*args[:-1], block_size=args[-1]))
    np.testing.assert_array_equal(want, got)


def test_pallas_interpret_close_to_oracle():
    q, k_new, v_new, pool_k, pool_v, tables, pos, bs = _rand_case(1)
    want = np.asarray(jax.jit(_oracle, static_argnums=(7,))(
        q, k_new, v_new, pool_k, pool_v, tables, pos, bs))
    got = pallas_pda(q[None, :, 0], k_new[None], v_new[None], pool_k[None],
                     pool_v[None], tables, pos, block_size=bs, interpret=True)
    np.testing.assert_allclose(want, np.asarray(got[0][:, None]),
                               rtol=2e-5, atol=2e-5)


def test_property_random_tables_ragged_pos():
    """Property sweep: random block tables, ragged pos (incl. empty and full
    slots), varied GQA shapes — ref stays bitwise-exact, Pallas stays close."""
    for seed in range(8):
        kv, g = [(1, 4), (2, 2), (2, 4), (4, 1)][seed % 4]
        case = _rand_case(seed + 10, s=3 + seed % 3, kv=kv, g=g,
                          hd=16, bs=2 + 2 * (seed % 2), mb=3 + seed % 4)
        q, k_new, v_new, pool_k, pool_v, tables, pos, bs = case
        want = np.asarray(jax.jit(_oracle, static_argnums=(7,))(*case))
        got = np.asarray(jax.jit(
            ref.paged_decode_attention,
            static_argnames=("block_size",))(*case[:-1], block_size=bs))
        np.testing.assert_array_equal(want, got, err_msg=f"seed {seed}")
        pk = pallas_pda(q[None, :, 0], k_new[None], v_new[None],
                        pool_k[None], pool_v[None], tables, pos,
                        block_size=bs, interpret=True)
        np.testing.assert_allclose(want, np.asarray(pk[0][:, None]),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"seed {seed}")


def test_ref_sliding_window_matches_oracle():
    q, k_new, v_new, pool_k, pool_v, tables, pos, bs = _rand_case(2)
    cap = tables.shape[1] * bs
    w = 6
    valid = jnp.arange(cap)[None, :] <= pos[:, None]
    valid &= jnp.arange(cap)[None, :] > pos[:, None] - w
    kc = gather_pool_ref(pool_k[None], tables, bs)[0]
    vc = gather_pool_ref(pool_v[None], tables, bs)[0]
    rows = jnp.arange(q.shape[0])
    kc = kc.at[rows, pos].set(k_new)
    vc = vc.at[rows, pos].set(v_new)
    want = np.asarray(jax.jit(ops.decode_attention)(q, kc, vc, valid))
    got = np.asarray(jax.jit(
        ref.paged_decode_attention, static_argnames=("block_size", "window"))(
        q, k_new, v_new, pool_k, pool_v, tables, pos, block_size=bs, window=w))
    np.testing.assert_array_equal(want, got)
    pk = pallas_pda(q[None, :, 0], k_new[None], v_new[None], pool_k[None],
                    pool_v[None], tables, pos, block_size=bs, window=w,
                    interpret=True)
    np.testing.assert_allclose(want, np.asarray(pk[0][:, None]),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# engine integration: preemption refill + budgeted resume on the paged path
# ---------------------------------------------------------------------------

def test_preemption_refill_then_budget_resume_matches_rollout(dense_setup):
    """One run exercising BOTH re-prefill paths over the paged decode step:
    a starved pool forces recompute preemption mid-drain, then budget
    suspension + mid-sequence resubmission finishes the requests — greedy
    tokens must equal the synchronized engine's."""
    cfg, _, params = dense_setup
    b, pl, mn = 4, 8, 12
    prompts = np.random.RandomState(21).randint(0, 250, (b, pl)).astype(
        np.int32)
    sync = RolloutEngine(cfg, max_new=mn, eos_id=TOK.eos_id, pad_id=TOK.pad_id,
                         greedy=True)
    ref_out = sync.generate(params, prompts, jax.random.PRNGKey(5))
    cont = ServingEngine(cfg, max_new=mn, eos_id=TOK.eos_id, pad_id=TOK.pad_id,
                         greedy=True, max_slots=3, block_size=4,
                         num_blocks=11, max_seq_len=pl + mn)
    pending = {cont.submit(prompts[i], budget=6): i for i in range(b)}
    done, rounds = {}, 0
    preempts = 0
    while pending:
        outs, resum = cont.run_to_budget(params)
        for o in outs:
            done[pending.pop(o.rid)] = o
            preempts += o.preemptions
        nxt = {}
        for req in resum:
            i = pending.pop(req.rid)
            preempts += req.preemptions   # resubmission starts a fresh count
            nxt[cont.submit(req.prompt, generated=req.generated,
                            max_new=mn - len(req.generated), budget=6)] = i
        pending = nxt
        rounds += 1
        assert rounds <= 5
    assert preempts > 0, "pool was never starved — shrink num_blocks"
    assert rounds > 1, "budget suspension never fired"
    for i, o in done.items():
        n = len(o.gen)
        assert n == ref_out.lengths[i]
        np.testing.assert_array_equal(np.asarray(o.gen),
                                      ref_out.tokens[i, pl:pl + n])
    cont.sched.check_invariants()


# ---------------------------------------------------------------------------
# footprint: the jitted step must not materialize the dense cache view
# ---------------------------------------------------------------------------

def _lowered_step(cfg, params, *, block_size, max_seq):
    eng = ServingEngine(cfg, max_new=4, eos_id=TOK.eos_id, pad_id=TOK.pad_id,
                        greedy=True, max_slots=4, block_size=block_size,
                        max_seq_len=max_seq)
    s = eng.max_slots
    tok = jnp.zeros((s, 1), jnp.int32)
    pos = jnp.zeros((s,), jnp.int32)
    done = jnp.ones((s,), bool)
    compiled = eng._step.lower(
        params, eng.cache.pool_k, eng.cache.pool_v,
        jnp.asarray(eng.sched.tables), tok, pos, done).compile()
    return eng, compiled


def test_step_materializes_no_dense_cache_view(dense_setup):
    """The acceptance property: no (n, S, MB*bs, kv, hd) buffer exists in
    the compiled step (gather_kv is gone from the decode path), and the
    step's temp footprint stays ~flat when max_blocks_per_seq grows 4x —
    the dense gather alone would grow it by 2*n*S*cap*kv*hd*4 bytes."""
    cfg, _, params = dense_setup
    bs = 8
    eng1, c1 = _lowered_step(cfg, params, block_size=bs, max_seq=4 * bs)
    eng2, c2 = _lowered_step(cfg, params, block_size=bs, max_seq=16 * bs)
    n, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    for eng, comp in ((eng1, c1), (eng2, c2)):
        cap = eng.cache.max_blocks_per_seq * bs
        dense_shape = f"f32[{n},{eng.max_slots},{cap},{kv},{hd}]"
        assert dense_shape not in comp.as_text(), \
            f"dense cache view {dense_shape} materialized in the jitted step"
    # temp growth far below one dense gather of the larger engine
    cap2 = eng2.cache.max_blocks_per_seq * bs
    dense_bytes = 2 * n * eng2.max_slots * cap2 * kv * hd * 4
    t1 = c1.memory_analysis().temp_size_in_bytes
    t2 = c2.memory_analysis().temp_size_in_bytes
    assert t2 - t1 < dense_bytes // 2, (t1, t2, dense_bytes)


# ---------------------------------------------------------------------------
# bucketed admission prefill
# ---------------------------------------------------------------------------

def test_prefill_bucket_shape():
    assert [prefill_bucket(n) for n in (1, 8, 9, 16, 17, 33)] == \
        [8, 8, 16, 16, 32, 64]


def test_bucketed_admission_bounds_compiles_and_matches_sync(dense_setup):
    """Varied-length online submits must compile one prefill per power-of-2
    BUCKET (not per length), and bucket padding must not change greedy
    outputs vs the synchronized engine fed the same (unpadded) prompts."""
    cfg, _, params = dense_setup
    lengths = [3, 5, 6, 7, 9, 11, 12, 13]
    mn = 6
    cont = ServingEngine(cfg, max_new=mn, eos_id=TOK.eos_id, pad_id=TOK.pad_id,
                         greedy=True, max_slots=2, block_size=4,
                         max_seq_len=max(lengths) + mn)
    sync = RolloutEngine(cfg, max_new=mn, eos_id=TOK.eos_id, pad_id=TOK.pad_id,
                         greedy=True)
    rng = np.random.RandomState(3)
    rid2prompt = {}
    for ln in lengths:
        prompt = rng.randint(0, 250, (ln,)).astype(np.int32)
        rid2prompt[cont.submit(prompt)] = prompt
    outs = cont.drain(params)
    assert sorted(o.rid for o in outs) == sorted(rid2prompt)
    buckets = {prefill_bucket(n) for n in lengths}
    n_prefill = cont._prefill._cache_size()
    assert n_prefill <= len(buckets), \
        f"{n_prefill} prefill compiles for buckets {sorted(buckets)}"
    # greedy outputs unchanged by the bucket padding (subset: one prompt per
    # bucket — each sync comparison compiles its own prefill/decode shapes)
    checked = {}
    for o in outs:
        checked.setdefault(prefill_bucket(len(rid2prompt[o.rid])), o)
    for o in checked.values():
        p = rid2prompt[o.rid]
        want = sync.generate(params, p[None], jax.random.PRNGKey(5))
        n = int(want.lengths[0])
        assert len(o.gen) == n
        np.testing.assert_array_equal(np.asarray(o.gen),
                                      want.tokens[0, len(p):len(p) + n])


# ---------------------------------------------------------------------------
# Pallas path end-to-end (subprocess — REPRO_PALLAS read at import)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os, sys, json
import jax, numpy as np
jax.config.update("jax_default_matmul_precision", "highest")
sys.path.insert(0, "src")
from repro.configs import get_smoke_config
from repro.core.rollout import RolloutEngine
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve.engine import ServingEngine

tok = ByteTokenizer()
cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
m = build_model(cfg)
params = m.init(cfg, jax.random.PRNGKey(0))
prompts = np.random.RandomState(0).randint(0, 250, (2, 8)).astype(np.int32)
sync = RolloutEngine(cfg, max_new=6, eos_id=tok.eos_id, pad_id=tok.pad_id,
                     greedy=True)
cont = ServingEngine(cfg, max_new=6, eos_id=tok.eos_id, pad_id=tok.pad_id,
                     greedy=True, max_slots=2, block_size=4)
a = sync.generate(params, prompts, jax.random.PRNGKey(5))
b = cont.generate(params, prompts, jax.random.PRNGKey(5))
print(json.dumps({"match": bool(np.array_equal(a.tokens, b.tokens)),
                  "lengths": a.lengths.tolist()}))
"""


def test_pallas_engine_greedy_bit_identity_subprocess():
    """Under REPRO_PALLAS=interpret the serving step runs the Pallas paged
    kernel (online softmax — logits differ in ulps from the dense path);
    greedy TOKEN sequences must still be identical to RolloutEngine."""
    import os
    env = dict(os.environ, REPRO_PALLAS="interpret")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["match"], "pallas paged decode diverged from sync greedy"
