"""Deterministic SAMPLED serving: per-request PRNG streams.

The contract under test (docs/serving.md § "Deterministic sampling"):
token ``t`` of a request with stream seed ``s`` is drawn with
``fold_in(fold_in(run_key, s), t)`` — a pure function of (params, prompt,
seed, t) — so sampled token sequences are BITWISE invariant to admission
order, slot count, prefill chunking, preemption pressure, the host KV
tier, and budget suspend/resume, and equal to the synchronized
``RolloutEngine``.  gen_logp carries the same bitwise guarantee except on
requests that were actually recompute-preempted (their re-prefilled KV
differs from decode-written KV by ulps — the same caveat the greedy suite
encodes by asserting tokens-only under preemption); tokens stay bitwise
even there.

Fixtures keep ``(pl + max_new) % block_size == 0``: at a block-UNaligned
capacity the dense and paged pools differ in shape and XLA may tile their
reductions differently, costing logp ulps even under greedy — a
pre-existing scope caveat, not a sampling one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.rollout import (RolloutEngine, request_stream, sample_tokens,
                                token_keys, truncate_logits)
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve.engine import ServingEngine
from repro.serve.paged_cache import PagedKVCache
from repro.serve.scheduler import AdmissionQueue, Request, Scheduler

TOK = ByteTokenizer()
SAMP = dict(temperature=0.9, top_p=0.9, top_k=40)
B, PL, MN, BS = 4, 8, 12, 4          # capacity 20 — block-aligned (see above)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(b=B, pl=PL, seed=0):
    return np.random.RandomState(seed).randint(0, 250, (b, pl)).astype(np.int32)


def _sync(cfg, **kw):
    return RolloutEngine(cfg, max_new=MN, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, **SAMP, **kw)


def _serve(cfg, **kw):
    return ServingEngine(cfg, max_new=MN, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, **SAMP, **kw)


def _rows(outs):
    """rid-ordered (tokens, logp) tuples — the bitwise comparison unit."""
    return {o.rid: (tuple(int(t) for t in o.gen),
                    tuple(np.asarray(o.gen_logp, np.float32).tolist()))
            for o in outs}


def _online(cfg, params, prompts, *, seeds=None, order=None, priorities=None,
            **ekw):
    """Submit each prompt with stream seed = its ROW index (regardless of
    submission order), drain, return row-index-keyed (tokens, logp)."""
    e = _serve(cfg, seed=7, max_seq_len=PL + MN, **ekw)
    order = list(range(len(prompts))) if order is None else order
    rid2row = {}
    for i in order:
        rid = e.submit(prompts[i], seed=i if seeds is None else seeds[i],
                       priority=0 if priorities is None else priorities[i])
        rid2row[rid] = i
    rows = _rows(e.drain(params))
    e.close()
    return {rid2row[rid]: v for rid, v in rows.items()}


def _sync_rows(res, pl):
    return {i: (tuple(int(t) for t in res.tokens[i, pl:pl + res.lengths[i]]),
                tuple(res.gen_logp[i, :res.lengths[i]].tolist()))
            for i in range(res.tokens.shape[0])}


# ---------------------------------------------------------------------------
# serving ≡ sync, bitwise, under sampling
# ---------------------------------------------------------------------------

def test_sampled_batch_bitcompat_with_sync(dense_setup):
    """generate() on the serving engine == the sync engine, tokens AND
    gen_logp bitwise, under temperature/top-p/top-k sampling."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    r1 = _sync(cfg).generate(params, prompts, jax.random.PRNGKey(7))
    r2 = _serve(cfg, max_slots=B, block_size=BS).generate(
        params, prompts, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    np.testing.assert_array_equal(r1.response_mask, r2.response_mask)
    np.testing.assert_array_equal(r1.lengths, r2.lengths)
    t = r2.gen_logp.shape[1]
    np.testing.assert_array_equal(r1.gen_logp[:, :t], r2.gen_logp)


def test_sampled_online_equals_sync(dense_setup):
    """submit(seed=i)/drain reproduces sync row ``i`` bitwise — the online
    path derives the SAME stream fold_in(run_key, i) the sync engine does."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    res = _sync(cfg).generate(params, prompts, jax.random.PRNGKey(7))
    assert _online(cfg, params, prompts, max_slots=B,
                   block_size=BS) == _sync_rows(res, PL)


# ---------------------------------------------------------------------------
# scheduling invariance
# ---------------------------------------------------------------------------

def test_sampled_invariant_to_schedule(dense_setup):
    """The sampled output of every request is bitwise identical across
    admission order, slot count, and prefill chunking — the per-request
    stream makes the draw a pure function of (params, prompt, seed, t)."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    base = _online(cfg, params, prompts, max_slots=B, block_size=BS)
    assert _online(cfg, params, prompts, max_slots=B, block_size=BS,
                   order=[2, 0, 3, 1]) == base
    assert _online(cfg, params, prompts, max_slots=2, block_size=BS) == base
    assert _online(cfg, params, prompts, max_slots=B, block_size=BS,
                   prefill_chunk=5) == base
    assert _online(cfg, params, prompts, max_slots=2, block_size=BS,
                   prefill_chunk=3, order=[3, 1, 2, 0]) == base


def test_sampled_preemption_tokens_invariant(dense_setup):
    """A starved pool (recompute preemption) and the host KV tier (swap
    preemption) never change any request's sampled TOKENS; gen_logp stays
    bitwise on requests that were never preempted and agrees to float32
    ulps on the preempted ones (re-prefilled KV vs decode-written KV —
    the greedy suite's preemption caveat, inherited verbatim)."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    base = _online(cfg, params, prompts, max_slots=B, block_size=BS)
    for ekw in (dict(num_blocks=11),
                dict(num_blocks=11, host_tier_blocks=16)):
        e = _serve(cfg, seed=7, max_seq_len=PL + MN, max_slots=B,
                   block_size=BS, **ekw)
        for i in range(B):
            e.submit(prompts[i], seed=i)
        outs = sorted(e.drain(params), key=lambda o: o.rid)
        e.close()
        assert any(o.preemptions for o in outs), "fixture lost its pressure"
        for o in outs:
            bt, bl = base[o.rid]
            assert tuple(int(t) for t in o.gen) == bt
            if o.preemptions == 0:
                assert tuple(np.asarray(o.gen_logp).tolist()) == bl
            else:
                np.testing.assert_allclose(np.asarray(o.gen_logp),
                                           np.asarray(bl, np.float32),
                                           rtol=0, atol=1e-5)


def test_sampled_budget_resume_continues_stream(dense_setup):
    """Budget-suspend + mid-sequence resubmission with the SAME stream seed
    draws the remaining tokens from the same stream positions — the
    chopped run lands bitwise on the uninterrupted run's tokens."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    base = _online(cfg, params, prompts, max_slots=B, block_size=BS)
    e = _serve(cfg, seed=7, max_seq_len=PL + MN, max_slots=B, block_size=BS)
    pending = {i: e.submit(prompts[i], seed=i, budget=4) for i in range(B)}
    rows = {}
    while pending:
        outs, resum = e.run_to_budget(params)
        got = _rows(outs)
        rid2row = {rid: i for i, rid in pending.items()}
        for rid, v in got.items():
            rows[rid2row[rid]] = v
        pending = {
            rid2row[r.rid]: e.submit(r.prompt, generated=r.generated,
                                     max_new=MN - len(r.generated),
                                     seed=rid2row[r.rid], budget=4)
            for r in resum}
    e.close()
    assert {i: t for i, (t, _) in rows.items()} == {
        i: t for i, (t, _) in base.items()}


# ---------------------------------------------------------------------------
# replay + stream independence
# ---------------------------------------------------------------------------

def test_replay_from_seed(dense_setup):
    """Same engine seed + same (prompt, stream seed) submissions => bitwise
    identical outputs on a FRESH engine; a different engine seed diverges."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    a = _online(cfg, params, prompts, max_slots=B, block_size=BS)
    b = _online(cfg, params, prompts, max_slots=B, block_size=BS)
    assert a == b
    e = _serve(cfg, seed=8, max_seq_len=PL + MN, max_slots=B, block_size=BS)
    for i in range(B):
        e.submit(prompts[i], seed=i)
    other = _rows(e.drain(params))
    e.close()
    assert any(other[i][0] != a[i][0] for i in range(B))


def test_stream_independence(dense_setup):
    """A request's draws never depend on which other requests share the
    engine: row 2 submitted ALONE equals row 2 from the full wave."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    full = _online(cfg, params, prompts, max_slots=B, block_size=BS)
    alone = _online(cfg, params, prompts[2:3], seeds=[2], max_slots=B,
                    block_size=BS)
    assert alone[0] == full[2]


def test_default_seed_is_rid(dense_setup):
    """submit() without ``seed`` uses the request id — replayable on a
    fresh engine because rids are assigned in submission order."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    explicit = _online(cfg, params, prompts, max_slots=B, block_size=BS)
    implicit = _online(cfg, params, prompts, seeds=[None] * B, max_slots=B,
                       block_size=BS)
    assert implicit == explicit


def test_generate_interleaved_calls_are_pure(dense_setup):
    """generate() derives streams from the PASSED key without persisting
    any engine key state (the old engine-wide ``self._key`` chain made a
    second call depend on the first): same inputs replay bitwise no matter
    what ran in between, on serving and sync engines alike."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    srv, sync = _serve(cfg, max_slots=B, block_size=BS), _sync(cfg)
    k1, k2 = jax.random.PRNGKey(7), jax.random.PRNGKey(11)
    first = srv.generate(params, prompts, k1)
    srv.generate(params, _prompts(seed=5), k2)      # interleaved, other key
    again = srv.generate(params, prompts, k1)
    np.testing.assert_array_equal(first.tokens, again.tokens)
    np.testing.assert_array_equal(first.gen_logp, again.gen_logp)
    s1 = sync.generate(params, prompts, k1)
    sync.generate(params, _prompts(seed=5), k2)
    s2 = sync.generate(params, prompts, k1)
    np.testing.assert_array_equal(s1.tokens, s2.tokens)
    np.testing.assert_array_equal(s1.gen_logp, s2.gen_logp)


# ---------------------------------------------------------------------------
# fused top-p / top-k truncation (unit)
# ---------------------------------------------------------------------------

def test_truncate_noop_is_exact():
    logits = jnp.asarray(np.random.RandomState(0).randn(3, 17), jnp.float32)
    assert truncate_logits(logits, top_p=1.0, top_k=0) is logits


def test_truncate_topk_keeps_k_largest_ties_low_id():
    logits = jnp.asarray([[1.0, 3.0, 3.0, 2.0, 3.0]])
    out = np.asarray(truncate_logits(logits, top_k=2))
    # three-way tie at 3.0: stable ranking keeps the two LOWEST token ids
    assert np.isfinite(out[0]).tolist() == [False, True, True, False, False]


def test_truncate_topp_smallest_sufficient_prefix():
    p = np.array([0.5, 0.3, 0.15, 0.05])
    logits = jnp.log(jnp.asarray(p[None], jnp.float32))
    # top_p strictly between prefix masses (0.5 < 0.75 < 0.8) so float32
    # cumsum roundoff cannot sit exactly on the cutoff: {0.5, 0.3} is the
    # smallest prefix whose mass reaches 0.75; rank 2 must be cut
    out = np.asarray(truncate_logits(logits, top_p=0.75))
    assert np.isfinite(out[0]).tolist() == [True, True, False, False]
    # survivor mass covers at least top_p of the original
    kept = p[np.isfinite(out[0])]
    assert kept.sum() >= 0.75
    # rank 0 always survives, even with a tiny top_p
    out = np.asarray(truncate_logits(logits, top_p=1e-9))
    assert np.isfinite(out[0]).tolist() == [True, False, False, False]


def test_truncate_topk_topp_compose():
    """top-p mass is computed AFTER the top-k mask renormalizes."""
    p = np.array([0.4, 0.3, 0.2, 0.1])
    logits = jnp.log(jnp.asarray(p[None], jnp.float32))
    # top_k=2 renormalizes {0.4, 0.3} -> {4/7, 3/7}; top_p=0.6 then keeps
    # only rank 0 (4/7 > 0.6 exclusive mass rule cuts rank 1? no: exclusive
    # mass of rank 1 is 4/7 < 0.6 -> kept); top_p=0.5 cuts rank 1
    out = np.asarray(truncate_logits(logits, top_k=2, top_p=0.6))
    assert np.isfinite(out[0]).tolist() == [True, True, False, False]
    out = np.asarray(truncate_logits(logits, top_k=2, top_p=0.5))
    assert np.isfinite(out[0]).tolist() == [True, False, False, False]


def test_sample_logp_is_untruncated_policy_logp():
    """The returned logp scores the drawn token under the UN-truncated
    temperature-scaled distribution (the importance-ratio quantity) —
    truncation only filters the draw."""
    rs = np.random.RandomState(1)
    logits = jnp.asarray(rs.randn(5, 33), jnp.float32)
    keys = token_keys(jax.vmap(
        lambda i: request_stream(jax.random.PRNGKey(3), i))(jnp.arange(5)), 0)
    tok, lp = sample_tokens(logits, keys, temperature=0.7, greedy=False,
                            top_p=0.5, top_k=4)
    ref = jax.nn.log_softmax(logits / 0.7, axis=-1)
    np.testing.assert_array_equal(
        np.asarray(lp),
        np.asarray(jnp.take_along_axis(ref, jnp.asarray(tok)[:, None],
                                       axis=-1)[:, 0]))
    # and every drawn token is inside the truncated set
    filt = np.asarray(truncate_logits(logits, top_p=0.5, top_k=4))
    assert all(np.isfinite(filt[i, int(t)]) for i, t in enumerate(tok))


def test_greedy_ignores_key_and_truncation():
    logits = jnp.asarray(np.random.RandomState(2).randn(4, 19), jnp.float32)
    a = sample_tokens(logits, None, temperature=1.0, greedy=True)
    b = sample_tokens(logits, jax.random.PRNGKey(9), temperature=1.0,
                      greedy=True, top_p=0.3, top_k=2)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_invalid_sampling_params_rejected(dense_setup):
    cfg, _, _ = dense_setup
    with pytest.raises(ValueError, match="top_p"):
        truncate_logits(jnp.zeros((1, 4)), top_p=0.0, top_k=1)
    with pytest.raises(ValueError, match="top_p"):
        ServingEngine(cfg, max_new=4, eos_id=1, pad_id=0, top_p=1.5)
    with pytest.raises(ValueError, match="top_k"):
        ServingEngine(cfg, max_new=4, eos_id=1, pad_id=0, top_k=-1)


# ---------------------------------------------------------------------------
# priority-aware admission (unit — no model)
# ---------------------------------------------------------------------------

def _req(rid, priority=0):
    return Request(rid=rid, prompt=np.zeros((4,), np.int32), max_new=4,
                   priority=priority)


def test_admission_queue_priority_then_fifo():
    q = AdmissionQueue()
    for rid, pr in [(0, 0), (1, 5), (2, 0), (3, 5), (4, 1)]:
        q.append(_req(rid, pr))
    q.check_invariants()
    assert [q.popleft().rid for _ in range(5)] == [1, 3, 4, 0, 2]
    with pytest.raises(IndexError):
        q.popleft()


def test_admission_queue_appendleft_front_of_class():
    q = AdmissionQueue()
    q.append(_req(0, 1))
    q.append(_req(1, 1))
    q.append(_req(2, 9))
    q.appendleft(_req(3, 1))           # preemption re-queue: front of class 1
    q.check_invariants()
    assert [r.rid for r in q] == [2, 3, 0, 1]
    assert q[0].rid == 2               # ...but class 9 still leads
    assert [q.popleft().rid for _ in range(4)] == [2, 3, 0, 1]


def test_admission_queue_uniform_priorities_is_fifo():
    q = AdmissionQueue()
    for rid in range(6):
        q.append(_req(rid))
    q.appendleft(_req(6))
    assert [q.popleft().rid for _ in range(7)] == [6, 0, 1, 2, 3, 4, 5]


def test_admission_queue_starvation_bypass():
    """A low-priority entry jumped ``starvation_limit`` times becomes the
    head regardless of priority — bulk traffic is delayed, never parked."""
    from repro.obs import MetricsRegistry
    m = MetricsRegistry()
    q = AdmissionQueue(starvation_limit=3, metrics=m)
    q.append(_req(0, 0))               # the would-starve entry
    for rid in range(1, 10):
        q.append(_req(rid, 5))
    admitted = [q.popleft().rid for _ in range(4)]
    q.check_invariants()
    # three high-priority admissions jump rid 0; the 4th pop is the bypass
    assert admitted == [1, 2, 3, 0]
    assert m.value("serve.priority.bypass") == 1
    # remaining high-priority entries drain FIFO
    assert [q.popleft().rid for _ in range(6)] == [4, 5, 6, 7, 8, 9]


def test_victim_is_lowest_priority_youngest(dense_setup):
    """ensure_capacity never preempts a strictly-higher-priority request
    while a lower-priority one runs; within a class, youngest first."""
    cfg, _, _ = dense_setup
    cache = PagedKVCache(cfg, num_blocks=5, block_size=4,
                         max_blocks_per_seq=4)
    sched = Scheduler(cache, max_slots=2)
    lo = _req(0, priority=0)
    hi = _req(1, priority=7)
    lo.max_new = hi.max_new = 8
    lo.prompt = hi.prompt = np.zeros((7,), np.int32)
    sched.submit(lo)
    sched.submit(hi)
    assert len(sched.admit()) == 2     # hi admitted SECOND (youngest)
    lo.cache_len = hi.cache_len = 8    # both need a 3rd block; 1 free
    pre = sched.ensure_capacity()
    # uniform-priority rule would evict hi (youngest); priority spares it
    assert [r.rid for r in pre] == [0]
    assert hi.slot != -1 and lo.slot == -1 and lo.preemptions == 1
    assert sched.waiting[0] is lo
    sched.check_invariants()


def test_priority_admission_order_on_engine(dense_setup):
    """With one slot, queued requests are admitted priority-first — visible
    as finish order — while each request's OUTPUT stays bitwise equal to
    the uniform-priority run (priorities steer WHEN, never WHAT)."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    base = _online(cfg, params, prompts, max_slots=B, block_size=BS)
    e = _serve(cfg, seed=7, max_seq_len=PL + MN, max_slots=1, block_size=BS)
    prio = [0, 3, 1, 9]
    rid2row = {e.submit(prompts[i], seed=i, priority=prio[i]): i
               for i in range(B)}
    outs = e.drain(params)
    e.close()
    # admission happens at the first step(), with all four queued: strict
    # priority order (9, 3, 1, 0)
    assert [rid2row[o.rid] for o in outs] == [3, 1, 2, 0]
    for o in outs:
        bt, bl = base[rid2row[o.rid]]
        assert tuple(int(t) for t in o.gen) == bt
        # a 1-slot engine decodes (1, V)-shaped steps, which XLA tiles
        # differently from the (4, V) base run — tokens stay bitwise, logp
        # agrees to ulps (the multi-slot invariance leg is bitwise: see
        # test_sampled_invariant_to_schedule)
        np.testing.assert_allclose(np.asarray(o.gen_logp),
                                   np.asarray(bl, np.float32),
                                   rtol=0, atol=1e-5)


def test_priorities_never_change_outputs(dense_setup):
    """Full sweep: random priorities + contention (2 slots) produce bitwise
    the outputs of the uniform-priority run."""
    cfg, _, params = dense_setup
    prompts = _prompts()
    base = _online(cfg, params, prompts, max_slots=B, block_size=BS)
    assert _online(cfg, params, prompts, max_slots=2, block_size=BS,
                   priorities=[2, 0, 5, 1]) == base
