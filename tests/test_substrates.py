"""Optimizer, schedules, checkpoint, data pipeline, rollout engine, HLO cost
parser, sharding rules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_smoke_config
from repro.core.rollout import RolloutEngine
from repro.data.prompts import PromptDataset, arithmetic_task, pattern_task
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_abstract_mesh
from repro.models.model import build_model
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         global_norm, wsd_schedule)
from repro.sharding import param_specs
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_numpy_reference(rng):
    params = {"w": jax.random.normal(rng, (4, 3))}
    grads = {"w": jax.random.normal(jax.random.fold_in(rng, 1), (4, 3))}
    state = adamw_init(params)
    lr, b1, b2, eps = 1e-2, 0.9, 0.95, 1e-8
    new, st = adamw_update(grads, state, params, lr=lr, betas=(b1, b2))
    g = np.asarray(grads["w"], np.float64)
    m = (1 - b1) * g
    v = (1 - b2) * g * g
    mhat, vhat = m / (1 - b1), v / (1 - b2)
    want = np.asarray(params["w"], np.float64) - lr * mhat / (np.sqrt(vhat) + eps)
    np.testing.assert_allclose(np.asarray(new["w"]), want, rtol=1e-5, atol=1e-6)
    assert int(st.step) == 1


def test_adamw_grad_clip(rng):
    params = {"w": jnp.zeros((10,))}
    grads = {"w": jnp.full((10,), 100.0)}
    state = adamw_init(params)
    new_clip, _ = adamw_update(grads, state, params, lr=1.0, grad_clip=1.0)
    new_raw, _ = adamw_update(grads, adamw_init(params), params, lr=1.0)
    # direction identical, clipped step not larger
    assert float(jnp.max(jnp.abs(new_clip["w"]))) <= float(
        jnp.max(jnp.abs(new_raw["w"]))) + 1e-6


def test_global_norm():
    tree = {"a": jnp.ones((3,)) * 2, "b": jnp.ones((4,)) * 3}
    want = np.sqrt(3 * 4 + 4 * 9)
    assert float(global_norm(tree)) == pytest.approx(want, rel=1e-6)


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=110)
    assert float(cos(jnp.int32(0))) == 0.0
    assert float(cos(jnp.int32(10))) == pytest.approx(1.0)
    assert float(cos(jnp.int32(110))) == pytest.approx(0.1, rel=1e-3)
    wsd = wsd_schedule(1.0, warmup=5, stable=10, decay=10)
    assert float(wsd(jnp.int32(7))) == pytest.approx(1.0)
    assert float(wsd(jnp.int32(25))) == pytest.approx(0.05, rel=1e-2)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(rng):
    tree = {"layers": {"w": jax.random.normal(rng, (4, 5)),
                       "b": jnp.arange(3, dtype=jnp.int32)},
            "head": jax.random.normal(jax.random.fold_in(rng, 1), (5,))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_pytree(path, tree, step=7)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        back = load_pytree(path, like)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_pattern_task_reward():
    task = pattern_task()
    ds = PromptDataset(task, max_prompt_len=16, seed=0)
    prompts, lens, metas = ds.sample(4)
    assert prompts.shape == (4, 16)
    target = metas[0]["target"]
    good = np.full((1, 8), target, np.int32)
    assert ds.score([metas[0]], good)[0] == 1.0
    bad = np.full((1, 8), (target + 1) % 255, np.int32)
    assert ds.score([metas[0]], bad)[0] == 0.0


def test_arithmetic_task_reward():
    task = arithmetic_task()
    ds = PromptDataset(task, max_prompt_len=16, seed=0)
    _, _, metas = ds.sample(1)
    tok = ByteTokenizer()
    right = np.array([tok.encode(str(metas[0]["sum"]), add_bos=False)
                      + [tok.eos_id]], np.int32)
    assert ds.score(metas, right)[0] == 1.0


# ---------------------------------------------------------------------------
# rollout engine
# ---------------------------------------------------------------------------

def test_rollout_stops_at_eos_and_masks(rng):
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, rng)
    tok = ByteTokenizer()
    eng = RolloutEngine(cfg, max_new=8, eos_id=tok.eos_id, pad_id=tok.pad_id,
                        temperature=1.0)
    prompts = np.random.default_rng(0).integers(
        0, 255, (4, 6)).astype(np.int32)
    res = eng.generate(params, prompts, jax.random.PRNGKey(0))
    assert res.tokens.shape[1] == 6 + 8
    for i in range(4):
        n = res.lengths[i]
        assert res.response_mask[i, :6].sum() == 0          # prompt unmasked
        assert res.response_mask[i, 6:6 + n].sum() == n     # response masked
        assert res.response_mask[i, 6 + n:].sum() == 0      # pad unmasked
        gen = res.tokens[i, 6:6 + n]
        if tok.eos_id in gen.tolist():
            assert gen.tolist().index(tok.eos_id) == n - 1  # stops at EOS
        assert (res.tokens[i, 6 + n:] == tok.pad_id).all()


def test_rollout_greedy_deterministic(rng):
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, rng)
    tok = ByteTokenizer()
    eng = RolloutEngine(cfg, max_new=6, eos_id=tok.eos_id, pad_id=tok.pad_id,
                        greedy=True)
    prompts = np.ones((2, 4), np.int32) * 65
    r1 = eng.generate(params, prompts, jax.random.PRNGKey(0))
    r2 = eng.generate(params, prompts, jax.random.PRNGKey(99))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def _mesh(shape=(2, 4)):
    # AbstractMesh: the sharding RULES only need shapes/names, not devices
    return make_abstract_mesh(shape, ("data", "model"))


def test_param_specs_divisibility(rng):
    mesh = _mesh()
    for arch in ("yi-6b", "mixtral-8x7b", "mamba2-1.3b", "whisper-large-v3"):
        cfg = get_smoke_config(arch)
        m = build_model(cfg)
        ps = jax.eval_shape(lambda: m.init(cfg, jax.random.PRNGKey(0)))
        specs = param_specs(cfg, ps, mesh, stage="train")
        flat_p = jax.tree.leaves(ps)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            for dim, ax in zip(leaf.shape, spec):
                if ax is None:
                    continue
                size = (np.prod([mesh.shape[a] for a in ax])
                        if isinstance(ax, tuple) else mesh.shape[ax])
                assert dim % size == 0, (arch, leaf.shape, spec)


def test_train_gen_layouts_differ(rng):
    mesh = _mesh()
    cfg = get_smoke_config("yi-6b")
    m = build_model(cfg)
    ps = jax.eval_shape(lambda: m.init(cfg, jax.random.PRNGKey(0)))
    t = param_specs(cfg, ps, mesh, stage="train")
    g = param_specs(cfg, ps, mesh, stage="gen", gen_mode="tp")
    t_flat = jax.tree.leaves(t, is_leaf=lambda x: isinstance(x, P))
    g_flat = jax.tree.leaves(g, is_leaf=lambda x: isinstance(x, P))
    assert any(a != b for a, b in zip(t_flat, g_flat))


# ---------------------------------------------------------------------------
# HLO cost parser
# ---------------------------------------------------------------------------

def test_hlo_cost_trip_count_multiplier():
    from repro.launch import hlo_cost
    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h.sum()
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)).compile()
    hc = hlo_cost.analyze_hlo(c.as_text())
    want = 2 * 8 * 16 * 16 * 5      # dot flops × trip count
    assert hc.flops == pytest.approx(want, rel=0.01)
    assert 5.0 in hc.trip_counts
