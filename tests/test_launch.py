"""Launch-layer tests: program builders, skip policy, capacity logic, and a
small-mesh lower+compile integration check (the dry-run mechanics at 8
devices instead of 512 so CI stays fast — tests/conftest keeps 1 real device;
here we only need the BUILDERS, not multi-device lowering)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES
from repro.launch import analysis, hlo_cost
from repro.launch.specs import (SkipPair, decode_capacity, effective_config,
                                train_batch_structs)


def test_effective_config_long_ctx_window():
    cfg = effective_config("yi-6b", "long_500k")
    assert cfg.sliding_window == 8192          # SWA override for dense arch
    cfg = effective_config("mixtral-8x7b", "long_500k")
    assert cfg.sliding_window == 4096          # native window preserved
    cfg = effective_config("mamba2-1.3b", "long_500k")
    assert cfg.is_attention_free               # untouched


def test_whisper_long_ctx_skipped():
    with pytest.raises(SkipPair):
        effective_config("whisper-large-v3", "long_500k")


def test_decode_capacity_ring():
    cfg = effective_config("yi-6b", "long_500k")
    assert decode_capacity(cfg, INPUT_SHAPES["long_500k"]) == 8192
    cfg = effective_config("yi-6b", "decode_32k")
    assert decode_capacity(cfg, INPUT_SHAPES["decode_32k"]) == 32768


def test_train_batch_structs_shapes():
    cfg = effective_config("qwen2-vl-72b", "train_4k")
    b = train_batch_structs(cfg, INPUT_SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096)
    assert b["old_logp"].shape == (256, 4095)
    assert b["vision_embeds"].shape == (256, cfg.vision_tokens, cfg.d_model)
    cfg = effective_config("whisper-large-v3", "train_4k")
    b = train_batch_structs(cfg, INPUT_SHAPES["train_4k"])
    assert b["frames"].shape == (256, 1500, 1280)


def test_model_flops_sane():
    cfg = effective_config("yi-6b", "train_4k")
    n = analysis.active_params(cfg)
    assert 5.5e9 < n < 7.5e9        # ~6B params
    cfg = effective_config("qwen1.5-110b", "train_4k")
    assert 95e9 < analysis.active_params(cfg) < 125e9
    moe = effective_config("llama4-maverick-400b-a17b", "train_4k")
    assert analysis.total_params(moe) > 5 * analysis.active_params(moe)


def test_hlo_collective_ring_factors():
    assert hlo_cost._ring_factor("all-reduce", 4) == pytest.approx(1.5)
    assert hlo_cost._ring_factor("all-gather", 16) == pytest.approx(15 / 16)
    assert hlo_cost._ring_factor("reduce-scatter", 4) == 3.0
    assert hlo_cost._ring_factor("all-reduce", 1) == 0.0


def test_hlo_parser_on_multidevice_program():
    """End-to-end parser check on a sharded scan program (1 device)."""
    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h.sum()

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((4, 8), jnp.float32),
        jax.ShapeDtypeStruct((3, 8, 8), jnp.float32)).compile()
    hc = hlo_cost.analyze_hlo(c.as_text())
    assert hc.flops == pytest.approx(2 * 4 * 8 * 8 * 3, rel=0.01)
    assert hc.bytes > 0
    assert hc.collective_bytes == 0.0
