"""REQUIRED per-architecture smoke tests: instantiate the reduced variant of
each assigned family (<=2 layers, d_model<=512, <=4 experts) and run one
forward + one GRPO train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.configs.base import RLConfig
from repro.core import grpo
from repro.models.model import build_model
from repro.optim import adamw_init


def _batch(cfg, b, s, key):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1), (b, cfg.vision_tokens, cfg.d_model))
    if cfg.arch_type == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.encoder_seq, cfg.d_model))
    return batch


def _train_batch(cfg, b, s, key):
    batch = _batch(cfg, b, s, key)
    batch.update({
        "response_mask": jnp.ones((b, s), jnp.float32).at[:, : s // 2].set(0),
        "advantages": jax.random.normal(jax.random.fold_in(key, 3), (b,)),
        "old_logp": -jnp.abs(jax.random.normal(
            jax.random.fold_in(key, 4), (b, s - 1))),
        "ref_logp": -jnp.abs(jax.random.normal(
            jax.random.fold_in(key, 5), (b, s - 1))),
    })
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_reduced_limits(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.arch_type == get_config(arch).arch_type  # same family


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward(arch, rng):
    cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, rng)
    b, s = 2, 16
    logits, aux = m.forward(params, cfg, _batch(cfg, b, s, rng))
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch, rng):
    cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
    rl = RLConfig(lr=1e-4)
    m = build_model(cfg)
    params = m.init(cfg, rng)
    opt = adamw_init(params)
    step = grpo.make_train_step(cfg, rl)
    b, s = 2, 16
    new_params, new_opt, metrics = jax.jit(step)(
        params, opt, _train_batch(cfg, b, s, rng))
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually changed and contain no NaNs
    changed = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(changed)) > 0
    for leaf in jax.tree.leaves(new_params):
        assert not np.any(np.isnan(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_decode_consistency(arch, rng):
    """prefill + decode must reproduce the teacher-forcing forward."""
    cfg = get_smoke_config(arch).replace(
        dtype="float32", remat=False, moe_capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init(cfg, rng)
    b, s, pl = 2, 16, 8
    batch = _batch(cfg, b, s, jax.random.fold_in(rng, 9))
    logits, _ = m.forward(params, cfg, batch)
    cache = m.init_cache(cfg, b, s)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :pl]
    lg, cache = m.prefill(params, cfg, pb, cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, pl - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(pl, pl + 4):
        lg, cache = m.decode(params, cfg, cache,
                             batch["tokens"][:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   rtol=2e-4, atol=2e-4)
