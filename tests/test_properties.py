"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import grpo
from repro.core.transfer_dock import (DispatchLedger, TransferDock,
                                      tcv_gb, tcv_td_gb)
from repro.data.tokenizer import ByteTokenizer
from repro.kernels import ops, ref
from repro.models import mamba2

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(1, 64), st.integers(1, 32), st.integers(2, 64))
@settings(**SETTINGS)
def test_tokenizer_roundtrip(seed, n, length):
    rng = np.random.default_rng(seed)
    text = "".join(chr(rng.integers(32, 127)) for _ in range(length))
    tok = ByteTokenizer()
    ids = tok.encode(text)
    assert tok.decode(ids) == text


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8), st.integers(2, 8))
@settings(**SETTINGS)
def test_advantage_translation_invariance(seed, g, n):
    """Group advantages are invariant to per-group reward shifts."""
    rng = np.random.default_rng(seed)
    r = rng.normal(size=(g, n)).astype(np.float32)
    shift = rng.normal(size=(g, 1)).astype(np.float32)
    a1 = np.asarray(grpo.group_advantages(jnp.asarray(r)))
    a2 = np.asarray(grpo.group_advantages(jnp.asarray(r + shift)))
    np.testing.assert_allclose(a1, a2, rtol=1e-3, atol=1e-3)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4), st.integers(1, 3),
       st.sampled_from([8, 16, 32]))
@settings(**SETTINGS)
def test_rope_preserves_norm(seed, b, h, d):
    """Rotation preserves the norm of every (x1, x2) pair."""
    key = jax.random.PRNGKey(seed)
    s = 8
    x = jax.random.normal(key, (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos, sin = ops.rope_tables(pos, d, 10_000.0)
    y = ref.rope(x, cos[:, :, None, :], sin[:, :, None, :])
    nx = np.linalg.norm(np.asarray(x), axis=-1)
    ny = np.linalg.norm(np.asarray(y), axis=-1)
    np.testing.assert_allclose(nx, ny, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_ssd_chunk_invariance(seed):
    """SSD output must not depend on the chunk size."""
    key = jax.random.PRNGKey(seed)
    b, s, h, p, g, n = 1, 32, 2, 4, 1, 8
    x = jax.random.normal(key, (b, s, h, p))
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h))) * 0.3
    B = jax.random.normal(jax.random.fold_in(key, 2), (b, s, g, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(key, 3), (b, s, g, n)) * 0.3
    y8, st8 = mamba2.ssd_scan(x, a, B, C, chunk=8)
    y16, st16 = mamba2.ssd_scan(x, a, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st8), np.asarray(st16),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(1, 6), st.integers(1, 50))
@settings(**SETTINGS)
def test_dock_conservation(S, n):
    """Every byte put is retrievable; warehouse shards partition the index
    space exactly."""
    dock = TransferDock(S, {"w": 0}, DispatchLedger())
    rows = np.arange(n * 3, dtype=np.float32).reshape(n, 3)
    dock.put("x", list(range(n)), rows, src_node=0)
    sizes = [len(wh.store.get("x", {})) for wh in dock.warehouses]
    assert sum(sizes) == n
    got = dock.get("w", "x", list(range(n)), dst_node=0)
    np.testing.assert_array_equal(got, rows)


@given(st.integers(1, 4096), st.integers(1, 64), st.integers(128, 8192),
       st.integers(1, 8), st.integers(128, 16384), st.integers(1, 8),
       st.integers(2, 16), st.integers(1, 128))
@settings(**SETTINGS)
def test_td_volume_always_smaller(G, N, PL, n, SL, M, C, S):
    """Eq (4) per-warehouse volume < Eq (2) centralized volume whenever
    S > 1 (metadata overhead never dominates)."""
    central = tcv_gb(G, N, 4, PL, n, SL, M)
    td = tcv_td_gb(G, N, 4, PL, n, SL, M, C, S)
    if S > 1:
        assert td < central * (1.0 + C) / S + 1e-9 or td < central


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5))
@settings(max_examples=15, deadline=None)
def test_grpo_loss_mask_invariance(seed, pad):
    """Adding fully-masked padding tokens must not change the loss."""
    from repro.configs.base import RLConfig
    key = jax.random.PRNGKey(seed)
    rl = RLConfig()
    b, t = 2, 6
    lp = -jnp.abs(jax.random.normal(key, (b, t)))
    old = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (b, t)))
    refp = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (b, t)))
    adv = jax.random.normal(jax.random.fold_in(key, 3), (b,))
    mask = jnp.ones((b, t))
    l1, _ = grpo.grpo_loss(lp, old, refp, adv, mask, rl)
    padz = jnp.zeros((b, pad))
    l2, _ = grpo.grpo_loss(
        jnp.concatenate([lp, padz - 1], 1),
        jnp.concatenate([old, padz - 2], 1),
        jnp.concatenate([refp, padz - 3], 1),
        adv, jnp.concatenate([mask, padz], 1), rl)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5, atol=1e-6)
