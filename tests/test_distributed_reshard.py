"""Allgather-swap on a REAL multi-device mesh: the generation-layout weights
and the H2D-restored update weights must be bit-identical to the originals,
and the ledger must account the D2H/H2D volumes."""
import json
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.core.resharding import Resharder
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.sharding import param_specs

cfg = get_smoke_config("mixtral-8x7b").replace(dtype="float32", remat=False)
m = build_model(cfg)
params = m.init(cfg, jax.random.PRNGKey(0))
mesh = make_mesh((2, 4), ("data", "model"))
t = param_specs(cfg, params, mesh, stage="train")
g = param_specs(cfg, params, mesh, stage="gen", gen_mode="tp")
tsh = jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                   is_leaf=lambda x: isinstance(x, P))
pd = jax.device_put(params, tsh)
host_ref = jax.tree.map(lambda x: np.asarray(x).copy(), params)

for two_step in (False, True):
    rs = Resharder(mesh, t, g, use_swap=True, paper_two_step=two_step)
    gen, stash, led = rs.to_generation(pd)
    for a, b in zip(jax.tree.leaves(host_ref), jax.tree.leaves(gen)):
        np.testing.assert_array_equal(a, np.asarray(b))
    # generation weights carry the GENERATION shardings
    flat_g = jax.tree.leaves(jax.tree.map(
        lambda s: NamedSharding(mesh, s), g,
        is_leaf=lambda x: isinstance(x, P)))
    for leaf, want in zip(jax.tree.leaves(gen), flat_g):
        assert leaf.sharding.spec == want.spec, (leaf.sharding, want)
    back, led = rs.to_update(stash, led)
    for a, b in zip(jax.tree.leaves(host_ref), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, np.asarray(b))
    assert led.d2h_bytes > 0 and led.h2d_bytes > 0
    pd = back
print(json.dumps({"ok": True}))
"""


def test_allgather_swap_multidevice():
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    assert json.loads(res.stdout.strip().splitlines()[-1])["ok"]
