"""PPO trainer + partial-rollout trainer integration tests."""
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core.partial import PartialRolloutTrainer
from repro.core.ppo_trainer import PPOTrainer
from repro.data.prompts import PromptDataset, pattern_task

TINY = ModelConfig(
    name="tiny", arch_type="dense", num_layers=2, d_model=128,
    vocab_size=512, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
    dtype="float32", remat=False)


def _ds():
    return PromptDataset(pattern_task(), max_prompt_len=12, seed=0)


def test_ppo_trainer_iteration():
    rl = RLConfig(max_prompt_len=12, max_response_len=8, lr=1e-4)
    tr = PPOTrainer(TINY, rl, _ds(), num_nodes=4, seed=0)
    assert "value_head" in tr.params
    st = tr.iteration(global_batch=4)
    assert np.isfinite(st.loss)
    assert st.reshard["d2h_bytes"] > 0        # dataflow engaged
    st2 = tr.iteration(global_batch=4)
    assert np.isfinite(st2.loss)


def test_ppo_routes_through_metadata_plane():
    """PPO inference/update go through request_metadata/mark_consumed (the
    dispatch ledger used to undercount PPO metadata traffic and consumed
    state was never recorded)."""
    rl = RLConfig(max_prompt_len=12, max_response_len=8, lr=1e-4)
    tr = PPOTrainer(TINY, rl, _ds(), num_nodes=4, seed=0)
    st = tr.iteration(global_batch=4)
    for state in ("actor_generation", "actor_inference", "ref_inference",
                  "reward", "advantages", "actor_update"):
        assert tr.dock.controllers[state].consumed == set(range(4)), state
    assert st.dispatch["metadata_msgs"] > 0
    # the update stage was dispatched by readiness, not raw indexing
    assert ("actor_update", (0, 1, 2, 3)) in st.trace


def test_pf_ppo_trainer_iteration():
    rl = RLConfig(max_prompt_len=12, max_response_len=8, lr=1e-4)
    tr = PPOTrainer(TINY, rl, _ds(), pf_filter=True, num_nodes=4, seed=0)
    st = tr.iteration(global_batch=8)
    assert np.isfinite(st.loss)


def test_partial_rollout_lifecycle():
    """Sequences finish after ceil(max_response/budget) rounds; groups only
    update once complete; pending stabilizes."""
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=16,
                  lr=1e-4, partial_rollout=True)
    tr = PartialRolloutTrainer(TINY, rl, _ds(), budget=6, num_nodes=4, seed=0)
    pendings = []
    for it in range(4):
        st = tr.iteration(global_batch=4)
        pendings.append(tr.pending_partials)
        assert np.isfinite(st.loss)
    # cohort 0 (8 sequences) must have finished by round 3 (6+6+4 >= 16)
    assert pendings[0] == 8
    assert pendings[2] <= 16 and pendings[3] <= 16
    # the update state consumed only complete groups
    consumed = tr.dock.controllers["actor_update"].consumed
    assert len(consumed) % rl.num_generations == 0
    assert len(consumed) > 0


def test_partial_rollout_budget_respected():
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=16,
                  lr=1e-4, partial_rollout=True)
    tr = PartialRolloutTrainer(TINY, rl, _ds(), budget=4, num_nodes=4, seed=0)
    tr.iteration(global_batch=2)
    for st in tr.partials.values():
        assert st.ngen <= 4


def test_partial_iteration_leaves_engine_cap_untouched():
    """Regression: the old bucket loop clobbered the shared engine's
    ``max_new`` (eng.max_new = budget), leaking the cap into any other
    trainer reusing that engine.  Budgets are per request now."""
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=16,
                  lr=1e-4, partial_rollout=True)
    tr = PartialRolloutTrainer(TINY, rl, _ds(), budget=4, num_nodes=4, seed=0)
    eng = tr.actor.engine
    assert eng.max_new == rl.max_response_len
    tr.iteration(global_batch=2)
    tr.iteration(global_batch=2)
    assert eng.max_new == rl.max_response_len


def test_partial_budget_clamped_to_response_cap():
    """Regression: when the budget does not divide max_response_len, resumed
    sequences used to overshoot the cap (ngen > max_response_len) while the
    assembled row silently truncated; the per-request max_new = remaining
    cap clamps each resume."""
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=12,
                  lr=1e-4, partial_rollout=True)
    tr = PartialRolloutTrainer(TINY, rl, _ds(), budget=8, num_nodes=4, seed=0)
    for _ in range(3):
        tr.iteration(global_batch=2)
        for st in tr.partials.values():
            assert st.ngen < rl.max_response_len   # cap would have finished it
    # every assembled row is consistent: the mask counts at most the cap,
    # and exactly the non-pad response tokens of its row
    pl, cap = rl.max_prompt_len, rl.max_prompt_len + rl.max_response_len
    rows = masks = 0
    for wh in tr.dock.warehouses:
        for idx, mask in wh.store.get("response_mask", {}).items():
            n = int(mask.sum())
            assert n <= rl.max_response_len
            tok = wh.store["tokens"][idx]
            assert tok.shape == (cap,) and mask.shape == (cap,)
            assert (mask[pl:pl + n] == 1.0).all() and mask[pl + n:].sum() == 0
            masks += 1
        rows += len(wh.store.get("tokens", {}))
    assert rows == masks and rows > 0
