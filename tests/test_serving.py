"""Continuous-batching serving subsystem (repro.serve)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.rollout import RolloutEngine
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve.engine import ServingEngine
from repro.serve.paged_cache import (PagedKVCache, blocks_for,
                                     gather_pool_pallas, gather_pool_ref)
from repro.serve.scheduler import Request, Scheduler

TOK = ByteTokenizer()


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(b, pl, seed=0):
    return np.random.RandomState(seed).randint(0, 250, (b, pl)).astype(np.int32)


def _engines(cfg, max_new, **kw):
    sync = RolloutEngine(cfg, max_new=max_new, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True)
    cont = ServingEngine(cfg, max_new=max_new, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True, **kw)
    return sync, cont


# ---------------------------------------------------------------------------
# paged cache
# ---------------------------------------------------------------------------

def test_pallas_gather_matches_ref(rng):
    pool = jax.random.normal(rng, (2, 40, 2, 16), jnp.float32)  # 4 blks + null
    tables = jnp.asarray(np.array([[2, 0, 4], [1, 3, 4]], np.int32))
    a = gather_pool_ref(pool, tables, 8)
    b = gather_pool_pallas(pool, tables, 8, interpret=True)
    assert a.shape == (2, 2, 24, 2, 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paged_view_matches_dense_prefill(dense_setup):
    """Prefill KV scattered into blocks, then gathered back, must reproduce
    the dense cache row content bit-for-bit."""
    cfg, m, params = dense_setup
    b, pl, bs = 3, 8, 4
    prompts = _prompts(b, pl)
    cache = m.init_cache(cfg, b, pl)
    _, cache = m.prefill(params, cfg, {"tokens": jnp.asarray(prompts)}, cache)

    pc = PagedKVCache(cfg, num_blocks=12, block_size=bs, max_blocks_per_seq=4)
    tables = np.full((b, 4), pc.null_block, np.int32)
    j = np.arange(pl)
    for i in range(b):
        blocks = [pc.alloc() for _ in range(blocks_for(pl, bs))]
        tables[i, :len(blocks)] = blocks
        flat = jnp.asarray(tables[i][j // bs] * bs + j % bs)
        pc.pool_k = pc.pool_k.at[:, flat].set(cache["k"][:, i])
        pc.pool_v = pc.pool_v.at[:, flat].set(cache["v"][:, i])
    view = pc.dense_view(tables)
    np.testing.assert_array_equal(np.asarray(view["k"][:, :, :pl]),
                                  np.asarray(cache["k"]))
    np.testing.assert_array_equal(np.asarray(view["v"][:, :, :pl]),
                                  np.asarray(cache["v"]))
    # decode over the paged view == decode over the dense cache
    tok = _prompts(b, 1, seed=9)
    padded = {
        "k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))),
        "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0))),
    }
    pos = jnp.full((b,), pl, jnp.int32)
    l_dense, _ = m.decode(params, cfg, padded, jnp.asarray(tok), pos)
    l_paged, _ = m.decode(params, cfg, view, jnp.asarray(tok), pos)
    np.testing.assert_array_equal(np.asarray(l_dense), np.asarray(l_paged))


def test_vector_pos_decode_matches_scalar(dense_setup):
    cfg, m, params = dense_setup
    b, pl = 3, 6
    cache = m.init_cache(cfg, b, 12)
    _, cache = m.prefill(params, cfg,
                         {"tokens": jnp.asarray(_prompts(b, pl))}, cache)
    tok = jnp.asarray(_prompts(b, 1, seed=2))
    l1, c1 = m.decode(params, cfg, cache, tok, jnp.int32(pl))
    l2, c2 = m.decode(params, cfg, cache, tok, jnp.full((b,), pl, jnp.int32))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(c1["k"]), np.asarray(c2["k"]))


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _sched(cfg, num_blocks=8, bs=4, mb=4, slots=2):
    cache = PagedKVCache(cfg, num_blocks=num_blocks, block_size=bs,
                         max_blocks_per_seq=mb)
    return Scheduler(cache, max_slots=slots), cache


def test_scheduler_admission_refill_eviction(dense_setup):
    cfg, _, _ = dense_setup
    sched, cache = _sched(cfg)
    reqs = [Request(rid=i, prompt=np.zeros((5,), np.int32), max_new=3)
            for i in range(4)]
    for r in reqs:
        sched.submit(r)
    admitted = sched.admit()
    # FIFO: rids 0, 1 fill both slots; each holds ceil(6/4)=2 blocks
    assert [r.rid for r in admitted] == [0, 1]
    assert cache.num_free == 4
    sched.check_invariants()
    # nothing admittable: no free slot
    assert sched.admit() == []
    # eviction frees blocks + slot; refill picks the FIFO head
    done = sched.finish(admitted[0].slot)
    assert done.rid == 0 and cache.num_free == 6
    sched.check_invariants()
    nxt = sched.admit()
    assert [r.rid for r in nxt] == [2]
    sched.check_invariants()


def test_scheduler_growth_and_preemption(dense_setup):
    cfg, _, _ = dense_setup
    sched, cache = _sched(cfg, num_blocks=5, bs=4, mb=4, slots=2)
    a = Request(rid=0, prompt=np.zeros((7,), np.int32), max_new=8)
    b = Request(rid=1, prompt=np.zeros((7,), np.int32), max_new=8)
    sched.submit(a)
    sched.submit(b)
    assert len(sched.admit()) == 2        # 2 blocks each, 1 left
    for r in (a, b):
        r.cache_len = 7
    assert sched.ensure_capacity() == []  # 8th token still fits block 2
    sched.check_invariants()
    a.cache_len = b.cache_len = 8         # both need a 3rd block; 1 free
    pre = sched.ensure_capacity()
    # oldest (rid 0) grabs the last block; youngest (rid 1) is preempted
    assert [r.rid for r in pre] == [1]
    assert b.slot == -1 and b.cache_len == 0 and b.preemptions == 1
    assert sched.waiting[0] is b          # re-queued at the FRONT
    sched.check_invariants()
    # rid 0 finishing frees enough for rid 1 to come back
    sched.finish(a.slot)
    assert [r.rid for r in sched.admit()] == [1]
    sched.check_invariants()


def test_scheduler_rejects_unschedulable(dense_setup):
    cfg, _, _ = dense_setup
    sched, _ = _sched(cfg, num_blocks=4, bs=4, mb=4, slots=1)
    with pytest.raises(ValueError):       # needs 5 blocks > max_blocks_per_seq
        sched.submit(Request(rid=0, prompt=np.zeros((10,), np.int32),
                             max_new=8))


# ---------------------------------------------------------------------------
# engine vs RolloutEngine
# ---------------------------------------------------------------------------

def test_generate_bitcompat_with_rollout(dense_setup):
    """S == B and block-aligned capacity: every jitted shape matches the
    synchronized engine, so greedy outputs are BIT-identical."""
    cfg, _, params = dense_setup
    b, pl, mn = 4, 8, 12
    prompts = _prompts(b, pl)
    sync, cont = _engines(cfg, mn, max_slots=b, block_size=4)
    r1 = sync.generate(params, prompts, jax.random.PRNGKey(5))
    r2 = cont.generate(params, prompts, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    np.testing.assert_array_equal(r1.response_mask, r2.response_mask)
    np.testing.assert_array_equal(r1.lengths, r2.lengths)
    np.testing.assert_array_equal(r1.gen_logp, r2.gen_logp)


def test_generate_refill_matches_rollout(dense_setup):
    """More requests than slots: waves of admission + refill must not change
    greedy outputs."""
    cfg, _, params = dense_setup
    b, pl, mn = 6, 8, 10
    prompts = _prompts(b, pl, seed=3)
    sync, cont = _engines(cfg, mn, max_slots=2, block_size=4)
    r1 = sync.generate(params, prompts, jax.random.PRNGKey(5))
    r2 = cont.generate(params, prompts, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    np.testing.assert_array_equal(r1.lengths, r2.lengths)


def test_generate_with_preemption_matches_rollout(dense_setup):
    """A starved block pool forces recompute-preemption mid-generation; the
    re-prefilled continuation must land on the same greedy tokens."""
    cfg, _, params = dense_setup
    b, pl, mn = 4, 8, 12
    prompts = _prompts(b, pl, seed=4)
    sync, cont = _engines(cfg, mn, max_slots=3, block_size=4,
                          num_blocks=11, max_seq_len=pl + mn)
    r1 = sync.generate(params, prompts, jax.random.PRNGKey(5))
    r2 = cont.generate(params, prompts, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_moe_serving_matches_rollout():
    cfg = get_smoke_config("mixtral-8x7b").replace(dtype="float32",
                                                   remat=False)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(1))
    prompts = _prompts(3, 6, seed=6)
    sync, cont = _engines(cfg, 8, max_slots=3, block_size=2)
    r1 = sync.generate(params, prompts, jax.random.PRNGKey(5))
    r2 = cont.generate(params, prompts, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_unsupported_arch_raises():
    cfg = get_smoke_config("mamba2-1.3b")
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, max_new=4, eos_id=TOK.eos_id, pad_id=TOK.pad_id)


# ---------------------------------------------------------------------------
# budgeted / mid-sequence requests (partial-rollout backend)
# ---------------------------------------------------------------------------

def test_run_to_budget_splits_finished_and_resumable(dense_setup):
    """Requests that exhaust their per-run budget come back resumable with
    their slots and blocks freed; EOS/cap finishes are reported normally."""
    cfg, _, params = dense_setup
    pl = 8
    prompts = _prompts(3, pl, seed=12)
    _, cont = _engines(cfg, 16, max_slots=3, block_size=4)
    r_short = cont.submit(prompts[0], max_new=3, budget=8)   # cap < budget
    r_a = cont.submit(prompts[1], max_new=16, budget=5)
    r_b = cont.submit(prompts[2], max_new=16, budget=5)
    outs, resum = cont.run_to_budget(params)
    assert [o.rid for o in outs] == [r_short]
    assert len(outs[0].gen) == 3
    assert sorted(r.rid for r in resum) == sorted([r_a, r_b])
    for req in resum:
        assert req.num_new == 5 and req.slot == -1
    assert cont.sched.idle and cont.cache.num_free == cont.cache.num_blocks
    cont.sched.check_invariants()


def test_mid_sequence_resume_matches_uninterrupted(dense_setup):
    """Greedy decode chopped into budget-4 installments (suspend, resubmit
    mid-sequence with the generated seed) lands on the same tokens as one
    uninterrupted run — resume is a re-prefill, the same path a recompute
    preemption takes."""
    cfg, _, params = dense_setup
    b, pl, mn = 3, 8, 12
    prompts = _prompts(b, pl, seed=7)
    sync, cont = _engines(cfg, mn, max_slots=b, block_size=4)
    ref = sync.generate(params, prompts, jax.random.PRNGKey(5))
    pending = {cont.submit(prompts[i], max_new=mn, budget=4): i
               for i in range(b)}
    done, rounds = {}, 0
    while pending:
        outs, resum = cont.run_to_budget(params)
        for o in outs:
            done[pending.pop(o.rid)] = o
        nxt = {}
        for req in resum:
            i = pending.pop(req.rid)
            nxt[cont.submit(req.prompt, generated=req.generated,
                            max_new=mn - len(req.generated), budget=4)] = i
        pending = nxt
        rounds += 1
        assert rounds <= 4
    assert sorted(done) == list(range(b))
    for i, o in done.items():
        n = len(o.gen)
        assert n == ref.lengths[i]
        np.testing.assert_array_equal(np.asarray(o.gen),
                                      ref.tokens[i, pl:pl + n])


def test_on_finish_never_fires_for_suspensions(dense_setup):
    cfg, _, params = dense_setup
    prompts = _prompts(2, 8, seed=13)
    _, cont = _engines(cfg, 16, max_slots=2, block_size=4)
    cont.submit(prompts[0], max_new=2, budget=6)
    cont.submit(prompts[1], max_new=16, budget=6)
    seen = []
    outs, resum = cont.run_to_budget(params, on_finish=seen.append)
    assert [o.rid for o in seen] == [o.rid for o in outs] == [0]
    assert [r.rid for r in resum] == [1]
    assert cont._on_finish is None       # restored after the run


def test_submit_rejects_bad_budget(dense_setup):
    cfg, _, _ = dense_setup
    _, cont = _engines(cfg, 8, max_slots=2, block_size=4)
    with pytest.raises(ValueError, match="budget"):
        cont.submit(np.zeros((4,), np.int32), budget=0)


def test_drain_refuses_budgeted_requests(dense_setup):
    """drain() returns finished outputs only — letting it run budgeted
    requests would strand their suspensions, so it refuses up front."""
    cfg, _, params = dense_setup
    _, cont = _engines(cfg, 8, max_slots=2, block_size=4)
    cont.submit(_prompts(1, 4, seed=14)[0], budget=2)
    with pytest.raises(RuntimeError, match="run_to_budget"):
        cont.drain(params)


# ---------------------------------------------------------------------------
# scheduler pressure: tiny pool, preemption firing, invariants every step
# ---------------------------------------------------------------------------

def test_scheduler_pressure_invariants_and_outputs(dense_setup):
    """Drive submit/step against a deliberately starved block pool: the
    recompute preemption must fire, Scheduler.check_invariants() must hold
    after EVERY step, and every request must eventually finish with the
    synchronized engine's greedy outputs."""
    cfg, _, params = dense_setup
    b, pl, mn = 6, 8, 12
    prompts = _prompts(b, pl, seed=11)
    sync, cont = _engines(cfg, mn, max_slots=4, block_size=4,
                          num_blocks=13, max_seq_len=pl + mn)
    ref = sync.generate(params, prompts, jax.random.PRNGKey(5))
    for i in range(b):
        cont.submit(prompts[i])
    outs, steps = [], 0
    while not cont.sched.idle:
        outs.extend(cont.step(params))
        cont.sched.check_invariants()
        steps += 1
        assert steps < 1000, "scheduler stopped making progress"
    assert sorted(o.rid for o in outs) == list(range(b))
    assert sum(o.preemptions for o in outs) > 0, "pool was never starved"
    for o in outs:
        n = len(o.gen)
        assert n == ref.lengths[o.rid]
        np.testing.assert_array_equal(np.asarray(o.gen),
                                      ref.tokens[o.rid, pl:pl + n])
    assert cont.cache.num_free == cont.cache.num_blocks


# ---------------------------------------------------------------------------
# online API + streaming
# ---------------------------------------------------------------------------

def test_online_budgets_and_latency(dense_setup):
    cfg, _, params = dense_setup
    _, cont = _engines(cfg, 16, max_slots=2, block_size=4, max_seq_len=24)
    budgets = [2, 7, 3, 5]
    for i, bud in enumerate(budgets):
        cont.submit(_prompts(1, 6, seed=i)[0], max_new=bud)
    outs = cont.drain(params)
    assert sorted(o.rid for o in outs) == [0, 1, 2, 3]
    by_rid = {o.rid: o for o in outs}
    for i, bud in enumerate(budgets):
        assert len(by_rid[i].gen) <= bud
        assert by_rid[i].latency_s > 0 and by_rid[i].ttft_s >= 0
    assert cont.sched.idle


def test_on_finish_streams_each_sample(dense_setup):
    """generate() must deliver every finished row the moment it completes,
    in dock-ready (cap-width) format matching the final RolloutResult."""
    cfg, _, params = dense_setup
    b, pl, mn = 4, 8, 6
    prompts = _prompts(b, pl, seed=8)
    seen = {}

    def on_finish(i, row, mask, n):
        seen[i] = (row.copy(), mask.copy(), n)

    _, cont = _engines(cfg, mn, max_slots=2, block_size=2)
    res = cont.generate(params, prompts, jax.random.PRNGKey(5),
                        on_finish=on_finish)
    assert sorted(seen) == list(range(b))
    for i in range(b):
        np.testing.assert_array_equal(seen[i][0], res.tokens[i])
        np.testing.assert_array_equal(seen[i][1], res.response_mask[i])
        assert seen[i][2] == res.lengths[i]


def test_trainer_serving_streams_into_dock():
    from repro.configs.base import RLConfig
    from repro.core.trainer import GRPOTrainer
    from repro.data.prompts import PromptDataset, pattern_task

    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=8,
                  rollout_engine="serving", serve_max_slots=2,
                  serve_block_size=4)
    ds = PromptDataset(pattern_task(), max_prompt_len=rl.max_prompt_len,
                       seed=0)
    tr = GRPOTrainer(cfg, rl, ds, num_nodes=2, seed=0)
    stats = tr.iteration(2)
    for v in (stats.loss, stats.kl, stats.reward_mean):
        assert np.isfinite(v)
    assert isinstance(tr.actor.engine, ServingEngine)


# ---------------------------------------------------------------------------
# transfer dock error message (satellite)
# ---------------------------------------------------------------------------

def test_transfer_dock_get_names_missing_field():
    from repro.core.transfer_dock import DispatchLedger, TransferDock

    dock = TransferDock(2, {"reward": 0}, DispatchLedger())
    dock.put("tokens", [0], np.zeros((1, 4), np.float32), src_node=0)
    with pytest.raises(KeyError) as ei:
        dock.get("reward", "advantages", [0], dst_node=0)
    msg = str(ei.value)
    assert "advantages" in msg and "sample 0" in msg and "reward" in msg