"""Exercise the ops->Pallas dispatch path end-to-end: a model forward with
REPRO_PALLAS=interpret must match the jnp path bit-for-bit-ish.  Runs in a
subprocess because the flag is read at import time."""
import json
import subprocess
import sys

SCRIPT = r"""
import os, sys, json
import jax, numpy as np
jax.config.update("jax_default_matmul_precision", "highest")
sys.path.insert(0, "src")
from repro.configs import get_smoke_config
from repro.models.model import build_model

cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
m = build_model(cfg)
params = m.init(cfg, jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                      cfg.vocab_size)}
logits, _ = m.forward(params, cfg, batch)
print(json.dumps({"sum": float(np.asarray(logits).sum()),
                  "absmax": float(np.abs(np.asarray(logits)).max())}))
"""


def _run(env_extra):
    import os
    env = dict(os.environ, **env_extra)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_pallas_interpret_matches_jnp_path():
    a = _run({"REPRO_PALLAS": ""})
    b = _run({"REPRO_PALLAS": "interpret"})
    assert abs(a["sum"] - b["sum"]) <= 1e-2 * max(abs(a["sum"]), 1.0)
    assert abs(a["absmax"] - b["absmax"]) <= 1e-3 * max(a["absmax"], 1.0)
