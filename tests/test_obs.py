"""Telemetry layer (repro.obs): tracer, registry, and the instrumented
serve/graph/dock layers — including the disabled-mode overhead contract
and greedy bit-identity with tracing ON."""
import json
import subprocess
import sys
import threading
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RLConfig
from repro.core.rollout import RolloutEngine
from repro.core.trainer import GRPOTrainer, build_grpo_graph
from repro.core.transfer_dock import (META_PER_SAMPLE, META_SCALAR_BYTES,
                                      CentralReplayBuffer, DispatchLedger,
                                      TransferDock)
from repro.data.prompts import PromptDataset, pattern_task
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.obs import NULL_SPAN, MetricsRegistry, Tracer, get_tracer
from repro.serve.engine import ServingEngine

ROOT = Path(__file__).resolve().parents[1]
TOK = ByteTokenizer()
GRPO_NODES = [n.name for n in build_grpo_graph().nodes]


class CountingTracer(Tracer):
    """Probe: counts every event that reaches the sink (the one place all
    spans/instants/counters land), so "disabled => nothing appended" is a
    checkable number rather than a hope."""

    def __init__(self, enabled=False):
        super().__init__(enabled)
        self.appends = 0

    def _append(self, ev):
        self.appends += 1
        super()._append(ev)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    m = build_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))
    return cfg, m, params


def _prompts(b, pl, seed=0):
    return np.random.RandomState(seed).randint(0, 250, (b, pl)).astype(np.int32)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_and_order():
    tr = Tracer(enabled=True)
    with tr.span("outer", cat="t"):
        with tr.span("inner", cat="t"):
            pass
        tr.instant("mark", cat="t")
    evs = tr.events
    assert [e["name"] for e in evs] == ["inner", "mark", "outer"]  # exit order
    outer = evs[2]
    inner = evs[0]
    # containment: the exporter's ts sort restores timeline order, and
    # Perfetto reconstructs nesting from interval containment
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    sorted_names = [e["name"] for e in tr.to_chrome()["traceEvents"]]
    assert sorted_names == ["outer", "inner", "mark"]


def test_span_args_mutable_until_exit():
    tr = Tracer(enabled=True)
    with tr.span("s", args=(args := {})):
        args["late"] = 1
    assert tr.events[0]["args"] == {"late": 1}


def test_concurrent_spans_get_distinct_tids():
    tr = Tracer(enabled=True)
    barrier = threading.Barrier(4)

    def work(i):
        barrier.wait()
        with tr.span(f"w{i}", cat="t"):
            pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events
    assert sorted(e["name"] for e in evs) == ["w0", "w1", "w2", "w3"]
    assert len({e["tid"] for e in evs}) == 4          # one track per thread
    assert all(e["pid"] == 0 for e in evs)


def test_disabled_tracer_is_contractually_free():
    tr = CountingTracer(enabled=False)
    # span: the module singleton, no allocation per call
    s1 = tr.span("a", cat="x", args={"k": 1})
    s2 = tr.span("b")
    assert s1 is NULL_SPAN and s2 is NULL_SPAN
    with s1:
        pass
    tr.instant("i", args={"k": 1})
    tr.counter("c", {"v": 3})
    assert tr.appends == 0
    assert tr.events == []
    # the process-default tracer ships disabled
    assert not get_tracer().enabled


def test_exporter_chrome_schema(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("s", cat="c", args={"n": 1}):
        tr.instant("i")
    tr.counter("cnt", {"a": 1, "b": 2})
    path = tr.export(str(tmp_path / "t.trace.json"))
    doc = json.load(open(path))
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert len(evs) == 3
    for ev in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)
        assert ev["ts"] >= 0
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)                            # exporter sorts
    x = [e for e in evs if e["ph"] == "X"]
    assert len(x) == 1 and x[0]["dur"] >= 0
    c = [e for e in evs if e["ph"] == "C"]
    assert c[0]["args"] == {"a": 1, "b": 2}


def test_tracer_clear_and_enable_toggle():
    tr = Tracer()
    tr.enable()
    tr.instant("i")
    assert len(tr.events) == 1
    tr.disable()
    tr.instant("j")
    assert len(tr.events) == 1
    tr.clear()
    assert tr.events == []


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_nearest_rank_percentiles():
    m = MetricsRegistry()
    for v in range(1, 101):
        m.observe("lat", v)
    s = m.summarize("lat")
    assert (s["p50"], s["p90"], s["p95"], s["p99"]) == (50, 90, 95, 99)
    assert s["count"] == 100 and s["min"] == 1 and s["max"] == 100
    assert m.percentile("lat", 0.5) == 50
    assert m.percentile("nope", 0.5) is None
    assert m.summarize("nope") == {}


def test_registry_snapshot_stable_and_serializable():
    m = MetricsRegistry()
    m.inc("b", 2)
    m.inc("a")
    m.set("g", 1.5)
    m.set_max("hw", 3)
    m.set_max("hw", 1)                                 # must not regress
    m.observe("h", 0.25)
    s1, s2 = m.snapshot(), m.snapshot()
    assert s1 == s2                                    # no writes => equal
    json.dumps(s1)                                     # serializable
    assert list(s1["counters"]) == ["a", "b"]          # sorted keys
    assert s1["gauges"]["hw"] == 3
    assert m.value("a") == 1 and m.value("missing", -1) == -1
    m.clear()
    assert m.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


# ---------------------------------------------------------------------------
# dock ledger: record_meta msgs contract (satellite)
# ---------------------------------------------------------------------------

def test_record_meta_msgs_contract():
    """PUT broadcasts one latency-bearing message per controller; a
    TransferDock metadata GET is co-located (msgs=0, bytes still counted);
    the CentralReplayBuffer baseline pays one real RPC per GET (plus
    cross-node bytes for workers off node 0).  This asymmetry is the
    paper's metadata-locality argument — pinned so nobody "fixes" it."""
    states = {"a": 0, "b": 1}
    dock = TransferDock(2, states, DispatchLedger())
    dock.put("f", [0, 1], np.zeros((2, 4), np.float32), src_node=0)
    assert dock.ledger.metadata_msgs == len(states)    # broadcast: msgs=nctl

    before_b, before_m = dock.ledger.metadata_bytes, dock.ledger.metadata_msgs
    dock.request_metadata("b", ["f"])                  # worker on node 1
    assert dock.ledger.metadata_msgs == before_m       # intranode: msgs=0
    assert dock.ledger.metadata_bytes == before_b + (
        META_PER_SAMPLE * META_SCALAR_BYTES)           # bytes still counted

    crb = CentralReplayBuffer(states, DispatchLedger())
    crb.put("f", [0, 1], np.zeros((2, 4), np.float32), src_node=0)
    m0, x0 = crb.ledger.metadata_msgs, crb.ledger.internode_bytes
    crb.request_metadata("a", ["f"])                   # worker ON node 0
    assert crb.ledger.metadata_msgs == m0 + 1          # real RPC: msgs=1
    assert crb.ledger.internode_bytes == x0            # but no cross bytes
    crb.request_metadata("b", ["f"])                   # worker OFF node 0
    assert crb.ledger.metadata_msgs == m0 + 2
    assert crb.ledger.internode_bytes == x0 + (
        META_PER_SAMPLE * META_SCALAR_BYTES)           # crosses the network


def test_ledger_emits_dock_counter_events():
    tr = Tracer(enabled=True)
    led = DispatchLedger(tracer=tr)
    led.record(100, cross=True, node=1)
    led.record(50, cross=False)
    led.record_meta(12, msgs=3)
    names = [e["name"] for e in tr.events]
    assert names == ["dock.bytes", "dock.bytes", "dock.metadata"]
    assert tr.events[1]["args"] == {"internode": 100, "intranode": 50}
    assert tr.events[2]["args"] == {"bytes": 12, "msgs": 3}
    assert all(e["ph"] == "C" and e["cat"] == "dock" for e in tr.events)


# ---------------------------------------------------------------------------
# serving engine: stats(), step telemetry, overhead + bit-identity
# ---------------------------------------------------------------------------

def test_engine_stats_and_step_telemetry(setup):
    cfg, _, params = setup
    tr = Tracer(enabled=True)
    eng = ServingEngine(cfg, max_new=6, eos_id=TOK.eos_id, pad_id=TOK.pad_id,
                        greedy=True, max_slots=2, block_size=4, tracer=tr)
    prompts = _prompts(3, 8)
    for p in prompts:
        eng.submit(p)
    outs = eng.drain(params)
    st = eng.stats()
    assert st["submitted"] == 3 and st["finished"] == len(outs) == 3
    assert st["steps"] == eng.steps > 0
    assert st["prefill_tokens"] == eng.prefill_tokens > 0
    assert st["decode_tokens"] > 0
    assert st["ttft_s"]["count"] == 3 and st["latency_s"]["count"] == 3
    assert st["ttft_s"]["p50"] <= st["latency_s"]["max"]

    evs = tr.events
    steps = [e for e in evs if e["name"] == "serve.step"]
    assert len(steps) == st["steps"]
    assert all(e["ph"] == "X" and e["cat"] == "serve" for e in steps)
    assert {"step", "live_slots", "waiting", "prefill_tokens",
            "finished"} <= set(steps[0]["args"])
    # cumulative token counters: one sample per step, final == registry
    tok_samples = [e for e in evs if e["name"] == "serve.tokens"]
    assert len(tok_samples) == st["steps"]
    assert tok_samples[-1]["args"]["prefill"] == st["prefill_tokens"]
    assert tok_samples[-1]["args"]["decode"] == st["decode_tokens"]
    # scheduler lifecycle instants on the same timeline
    inames = {e["name"] for e in evs if e["ph"] == "i"}
    assert {"serve.admit", "serve.finish"} <= inames
    fin = [e for e in evs if e["name"] == "serve.finish"]
    assert len(fin) == 3 and all("rid" in e["args"] for e in fin)


def test_generate_bitcompat_with_tracer_enabled(setup):
    """The acceptance property survives tracing: greedy ServingEngine with
    an ENABLED tracer is still token- and logp-identical to the sync
    engine (instrumentation changed the schedule's visibility, not math)."""
    cfg, _, params = setup
    b, pl, mn = 4, 8, 12        # S == B, block-aligned (the bitwise scope)
    prompts = _prompts(b, pl, seed=2)
    sync = RolloutEngine(cfg, max_new=mn, eos_id=TOK.eos_id,
                         pad_id=TOK.pad_id, greedy=True)
    tr = Tracer(enabled=True)
    cont = ServingEngine(cfg, max_new=mn, eos_id=TOK.eos_id, pad_id=TOK.pad_id,
                         greedy=True, max_slots=b, block_size=4, tracer=tr)
    r1 = sync.generate(params, prompts, jax.random.PRNGKey(5))
    r2 = cont.generate(params, prompts, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    np.testing.assert_array_equal(r1.response_mask, r2.response_mask)
    np.testing.assert_array_equal(r1.gen_logp, r2.gen_logp)
    assert any(e["name"] == "serve.step" for e in tr.events)


def test_disabled_tracer_adds_nothing_to_serving_steps(setup):
    """Overhead guard: a full serving run with the tracer disabled must
    append ZERO events and allocate ZERO span objects (every span() call
    returns the module singleton) — counter-based, immune to CPU noise."""
    cfg, _, params = setup
    tr = CountingTracer(enabled=False)
    eng = ServingEngine(cfg, max_new=6, eos_id=TOK.eos_id, pad_id=TOK.pad_id,
                        greedy=True, max_slots=2, block_size=4, tracer=tr)
    for p in _prompts(3, 8, seed=4):
        eng.submit(p)
    outs = eng.drain(params)
    assert len(outs) == 3
    assert tr.appends == 0 and tr.events == []
    assert eng.tracer.span("probe") is NULL_SPAN
    # the registry keeps counting regardless — stats() is always available
    assert eng.stats()["finished"] == 3


# ---------------------------------------------------------------------------
# trainer end-to-end: graph spans, dock counters, export + report CLI
# ---------------------------------------------------------------------------

def test_trainer_trace_end_to_end(tmp_path):
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=8,
                  rollout_engine="serving", serve_max_slots=2,
                  serve_block_size=4,
                  trace_path=str(tmp_path / "run.trace.json"))
    ds = PromptDataset(pattern_task(), max_prompt_len=rl.max_prompt_len,
                       seed=0)
    trainer = GRPOTrainer(cfg, rl, ds, num_nodes=2, seed=0)
    assert trainer.tracer.enabled                      # trace_path enables it
    stats = trainer.iteration(2)

    evs = trainer.tracer.events
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    # every graph node produced a stage span, tagged with its cluster node
    for node in GRPO_NODES:
        spans = by_name.get(f"stage.{node}")
        assert spans, f"no stage span for {node}"
        assert all(e["cat"] == "graph" for e in spans)
        assert all("cluster_node" in e["args"] for e in spans)
    # the bare (node, idxs) trace tuples are KEPT for bit-identity tests,
    # and every tuple has a span whose idxs match exactly
    assert stats.trace and all(isinstance(t, tuple) for t in stats.trace)
    span_idxs = {(e["args"]["node"], tuple(e["args"]["idxs"]))
                 for e in evs if e.get("cat") == "graph"}
    for name, idxs in stats.trace:
        assert (name, tuple(int(i) for i in idxs)) in span_idxs
    # layout edges + iteration envelope + dock/serve telemetry all landed
    assert "reshard.to_generation" in by_name
    assert "reshard.to_update" in by_name
    assert by_name["iteration"][0]["args"]["iteration"] == 0
    assert "dock.bytes" in by_name and "serve.step" in by_name
    assert by_name["dock.bytes"][-1]["args"]["intranode"] > 0

    # export honors rl.trace_path and the report CLI digests the file
    path = trainer.export_trace()
    assert path == rl.trace_path and Path(path).exists()
    doc = json.load(open(path))
    ts = [e["ts"] for e in doc["traceEvents"]]
    assert ts == sorted(ts) and len(ts) == len(evs)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "trace_report.py"), path,
         "--expect", ",".join(GRPO_NODES)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    for node in GRPO_NODES:
        assert node in proc.stdout
    assert "dock.bytes" in proc.stdout

    # --expect flags a node that never ran
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "trace_report.py"), path,
         "--expect", "no_such_node"],
        capture_output=True, text=True)
    assert proc.returncode == 1 and "no_such_node" in proc.stderr


def test_export_trace_requires_a_path():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=8)
    ds = PromptDataset(pattern_task(), max_prompt_len=rl.max_prompt_len,
                       seed=0)
    trainer = GRPOTrainer(cfg, rl, ds, num_nodes=2, seed=0)
    assert not trainer.tracer.enabled                  # no path => default
    with pytest.raises(ValueError, match="trace path"):
        trainer.export_trace()
