"""Transfer dock + resharding flow behaviour tests — the paper's core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.resharding import Resharder, per_device_bytes
from repro.core.transfer_dock import (CentralReplayBuffer, DispatchLedger,
                                      TransferDock, cv_gb, dispatch_time_s,
                                      tcv_gb, tcv_td_gb)
from repro.launch.mesh import make_mesh
from jax.sharding import PartitionSpec as P

STATES = {"actor_generation": 0, "actor_inference": 0, "ref_inference": 1,
          "reward": 2, "actor_update": 0}


def _dock(S=4):
    return TransferDock(S, STATES, DispatchLedger())


# ---------------------------------------------------------------------------
# transfer dock
# ---------------------------------------------------------------------------

def test_dock_put_get_roundtrip():
    dock = _dock()
    rows = np.arange(24, dtype=np.float32).reshape(6, 4)
    dock.put("x", list(range(6)), rows, src_node=0)
    got = dock.get("actor_update", "x", [3, 1, 5], dst_node=0)
    np.testing.assert_array_equal(got, rows[[3, 1, 5]])


def test_dock_metadata_readiness():
    dock = _dock()
    dock.put("a", [0, 1, 2], np.zeros((3, 2), np.float32), src_node=0)
    # state sees samples with field "a" but not ones needing "b"
    assert dock.request_metadata("reward", ["a"]) == [0, 1, 2]
    assert dock.request_metadata("reward", ["a", "b"]) == []
    dock.put("b", [1], np.zeros((1, 2), np.float32), src_node=0)
    assert dock.request_metadata("reward", ["a", "b"]) == [1]
    dock.mark_consumed("reward", [1])
    assert dock.request_metadata("reward", ["a", "b"]) == []


def test_dock_get_empty_idxs_well_shaped():
    """Streaming/graph consumers poll with whatever is ready — an empty
    request must return an empty batch of the field's TRUE row shape/dtype
    (remembered at first put), not an invented (0, 0) float32."""
    dock = _dock()
    dock.put("x", [0, 1], np.zeros((2, 3, 4), np.int32), src_node=0)
    got = dock.get("actor_update", "x", [], dst_node=0)
    assert got.shape == (0, 3, 4) and got.dtype == np.int32
    # the prototype survives clear(): row geometry is config-determined
    dock.clear()
    got = dock.get("actor_update", "x", [], dst_node=0)
    assert got.shape == (0, 3, 4) and got.dtype == np.int32
    # a field nobody has EVER produced has no prototype — that is an error,
    # not a made-up width/dtype lying to streaming consumers
    with pytest.raises(KeyError, match="nope.*before any put"):
        dock.get("actor_update", "nope", [], dst_node=0)


def test_controller_available_limit():
    dock = _dock()
    dock.put("a", list(range(6)), np.zeros((6, 2), np.float32), src_node=0)
    ctl = dock.controllers["reward"]
    assert ctl.available(["a"]) == [0, 1, 2, 3, 4, 5]
    assert ctl.available(["a"], limit=2) == [0, 1]
    assert ctl.available(["a"], limit=0) == []
    assert ctl.available(["a"], limit=99) == [0, 1, 2, 3, 4, 5]
    dock.mark_consumed("reward", [0, 1])
    assert ctl.available(["a"], limit=2) == [2, 3]
    assert dock.request_metadata("reward", ["a"], limit=3) == [2, 3, 4]


def test_metadata_requests_intranode_for_dock_cross_for_central():
    """Paper Table 1: TDControllers are co-located with their worker, so
    metadata requests never cross the network; the centralized buffer pins
    its controller to node 0, so every off-node worker's request does."""
    states = {"ref_inference": 1}           # worker lives on node 1
    td = TransferDock(2, states, DispatchLedger())
    td.put("x", [0], np.zeros((1, 2), np.float32), src_node=1)
    before = td.ledger.internode_bytes
    td.request_metadata("ref_inference", ["x"])
    assert td.ledger.internode_bytes == before      # intranode metadata
    assert td.ledger.metadata_bytes > 0

    cb = CentralReplayBuffer(states, DispatchLedger())
    cb.put("x", [0], np.zeros((1, 2), np.float32), src_node=1)
    before = cb.ledger.internode_bytes
    cb.request_metadata("ref_inference", ["x"])
    assert cb.ledger.internode_bytes > before       # crossed the network
    # a worker that happens to sit on node 0 stays intranode even centrally
    cb0 = CentralReplayBuffer({"actor_update": 0}, DispatchLedger())
    cb0.put("x", [0], np.zeros((1, 2), np.float32), src_node=0)
    before = cb0.ledger.internode_bytes
    cb0.request_metadata("actor_update", ["x"])
    assert cb0.ledger.internode_bytes == before


def test_dock_sharding_across_warehouses():
    dock = _dock(S=4)
    dock.put("x", list(range(8)), np.zeros((8, 10), np.float32), src_node=0)
    assert all(len(wh.store["x"]) == 2 for wh in dock.warehouses)


def test_td_parallel_dispatch_faster_than_central():
    """The linearity mechanism: S warehouses split the busiest-link load."""
    rows = np.zeros((64, 65536), np.float32)   # ~16 MB: data-plane dominated
    td = _dock(S=4)
    td.put("x", list(range(64)), rows, src_node=99)   # all cross-node
    td.get("actor_update", "x", list(range(64)), dst_node=99)
    cb = CentralReplayBuffer(STATES, DispatchLedger())
    cb.put("x", list(range(64)), rows, src_node=99)
    cb.get("actor_update", "x", list(range(64)), dst_node=99)
    t_td = td.ledger.simulated_dispatch_time
    t_cb = cb.ledger.simulated_dispatch_time
    assert t_td < t_cb
    assert t_cb / t_td > 3.0   # ~S× with S=4


def test_dispatch_eq_table1_row():
    """Reproduce Table 1 rows: G=256 N=8 PL=2K n=5 SL=8K M=3 B=4 -> TCV≈0.96GB,
    T100≈9.92s (within rounding of the paper's table)."""
    tcv = tcv_gb(G=256, N=8, B=4, PL=2048, n=5, SL=8192, M=3)
    assert abs(tcv - 0.96) < 0.05
    t100 = dispatch_time_s(tcv, 100 * 1024 ** 2)   # 100 MB/s links
    assert abs(t100 - 9.92) < 0.6
    # Eq (4): S warehouses divide the volume
    td = tcv_td_gb(G=256, N=8, B=4, PL=2048, n=5, SL=8192, M=3, C=5, S=16)
    assert td < tcv / 10


def test_cv_monotone_in_load():
    a = cv_gb(256, 8, 4, 2048, 5, 8192, 3)
    b = cv_gb(512, 8, 4, 2048, 5, 8192, 3)
    c = cv_gb(256, 16, 4, 2048, 5, 8192, 3)
    assert b == 2 * a and c == 2 * a


# ---------------------------------------------------------------------------
# resharding flow
# ---------------------------------------------------------------------------

def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _tiny_params(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (8, 16)),
            "w2": jax.random.normal(k2, (16, 4))}


def test_allgather_swap_roundtrip(rng):
    mesh = _mesh11()
    specs = {"w1": P("data", "model"), "w2": P("model", "data")}
    gspecs = {"w1": P(None, "model"), "w2": P("model", None)}
    params = _tiny_params(rng)
    rs = Resharder(mesh, specs, gspecs, use_swap=True)
    gen, stash, led = rs.to_generation(params)
    # generation weights numerically identical
    for k in params:
        np.testing.assert_array_equal(np.asarray(gen[k]),
                                      np.asarray(params[k]))
    kind, host = stash
    assert kind == "host"
    # host copies live in host memory (pinned_host) on this backend
    back, led = rs.to_update(stash, led)
    for k in params:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(params[k]))
    assert led.d2h_bytes > 0 and led.h2d_bytes > 0
    assert led.swap_time_s > 0


def test_paper_two_step_matches_fused(rng):
    mesh = _mesh11()
    specs = {"w1": P("data", "model"), "w2": P("model", "data")}
    gspecs = {"w1": P(None, "model"), "w2": P("model", None)}
    params = _tiny_params(rng)
    a = Resharder(mesh, specs, gspecs, use_swap=True, paper_two_step=True)
    b = Resharder(mesh, specs, gspecs, use_swap=True, paper_two_step=False)
    ga, _, la = a.to_generation(params)
    gb, _, lb = b.to_generation(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(ga[k]), np.asarray(gb[k]))
    # the literal two-step pays a temp allgather buffer; fused does not
    assert la.peak_bytes >= lb.peak_bytes


def test_naive_keeps_redundant_memory(rng):
    mesh = _mesh11()
    specs = {"w1": P("data", "model"), "w2": P("model", "data")}
    gspecs = {"w1": P(None, "model"), "w2": P("model", None)}
    params = _tiny_params(rng)
    swap = Resharder(mesh, specs, gspecs, use_swap=True)
    naive = Resharder(mesh, specs, gspecs, use_swap=False)
    _, _, led_s = swap.to_generation(params)
    _, stash_n, led_n = naive.to_generation(params)
    assert stash_n[0] == "device"      # update weights never left the device
    # the swap path's timeline ends LOWER by exactly the update partition
    end_s = led_s.timeline()[-1][1]
    end_n = led_n.timeline()[-1][1]
    assert end_n - end_s == swap.redundancy_bytes(params)


def test_per_device_bytes_uneven_padding():
    mesh = make_mesh((1, 1), ("data", "model"))
    leaf = jax.ShapeDtypeStruct((10, 7), jnp.float32)
    assert per_device_bytes(leaf, P(None, None), mesh) == 280
