"""Fused-kernel micro-benchmarks (the paper's "fused kernels" feature row).

Times the jnp reference path on CPU (wall) and reports the Pallas kernel's
VMEM working set + MXU alignment — the TPU-relevant derived quantities.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6     # us


def run():
    print("# kernel micro-benchmarks (CPU ref path wall; TPU kernel is the "
          "target)")
    print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)
    rows, d = 4096, 1024

    x = jax.random.normal(key, (rows, d), jnp.float32)
    w = jnp.ones((d,))
    us = _time(jax.jit(lambda a, b: ref.rmsnorm(a, b)), x, w)
    print(f"rmsnorm_{rows}x{d},{us:.1f},vmem_tile_KB="
          f"{256 * d * 4 / 1024:.0f}")

    g = jax.random.normal(key, (rows, d))
    u = jax.random.normal(jax.random.fold_in(key, 1), (rows, d))
    us = _time(jax.jit(ref.swiglu), g, u)
    print(f"swiglu_{rows}x{d},{us:.1f},fused_hbm_saving_MB="
          f"{rows * d * 4 / 1e6:.1f}")

    b, s, h, hd = 4, 1024, 8, 128
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, hd))
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, s, 2, hd))
    us = _time(jax.jit(lambda q, k, v: ops.attention(q, k, v)), q, k, v)
    flops = 4 * b * h * s * s * hd / 2  # causal
    print(f"flash_attention_b{b}_s{s},{us:.1f},GFLOP={flops/1e9:.2f}")

    t, dd, f, e = 1024, 512, 1024, 8
    gs = jnp.full((e,), t // e, jnp.int32)
    xg = jax.random.normal(key, (t, dd))
    wg = jax.random.normal(jax.random.fold_in(key, 4), (e, dd, f))
    us = _time(jax.jit(lambda x, w, g: ops.gmm(x, w, g)), xg, wg, gs)
    print(f"gmm_t{t}_e{e},{us:.1f},active_GFLOP={2*t*dd*f/1e9:.2f}")

    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos, sin = ops.rope_tables(pos, hd, 1e4)
    us = _time(jax.jit(lambda x, c, s_: ops.apply_rope(x, c[:, :, None, :],
                                                       s_[:, :, None, :])),
               q, cos, sin)
    print(f"rope_b{b}_s{s},{us:.1f},rotated_MB={q.size*4/1e6:.1f}")
    return True


if __name__ == "__main__":
    run()
