"""Host-tier KV swap: swap-preemption vs recompute-preemption A/B.

Two measurements on the same starved-pool serving setup (small device
pool, prompts long relative to generation — the regime where preemption
hurts and re-prefill is the dominant waste):

  * READMISSION COST — the same workload with the host tier off
    (recompute preemption: a victim's KV is dropped, re-admission
    re-prefills everything) vs on (swap preemption: reclaimed indexed
    blocks spill to host RAM and stream back on re-admission).  The
    metric is ``serve.readmit_prefill_tokens`` — prefill tokens issued
    for requests that had already been admitted once.  Swap must beat
    recompute by the asserted ratio; greedy outputs must be bitwise
    identical between the two runs (the tier's correctness contract).
  * PREFIX HIT-RATE — GRPO-shaped repeats (same prompts resubmitted
    after the pool churned past them) with a device-only index vs the
    tiered device+host index.  Device-only forgets a prefix the moment
    its blocks are reclaimed; the tier keeps matching from host, so
    shared (not re-prefilled) rows go up.

``PYTHONPATH=src python -m benchmarks.bench_swap`` or
``python -m benchmarks.run swap`` (writes BENCH_swap.json; key asserts
run in CI — see .github/workflows/ci.yml and docs/observability.md).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve.engine import ServingEngine

PL = 16            # prompt head worth preserving ...
MAX_NEW = 24       # ... and decode long enough that survivors churn the pool
BLOCK = 4
SLOTS = 3
NUM_BLOCKS = 16    # admits a full wave but not its decode growth: preemption
#                    fires, and the survivors' continued allocation reclaims
#                    (= spills) the victim's blocks while it waits
HOST_BLOCKS = 64


def _serve(cfg, params, prompts, host_blocks, repeats=1):
    tok = ByteTokenizer()
    eng = ServingEngine(cfg, max_new=MAX_NEW, eos_id=tok.eos_id,
                        pad_id=tok.pad_id, greedy=True, max_slots=SLOTS,
                        block_size=BLOCK, num_blocks=NUM_BLOCKS,
                        max_seq_len=PL + MAX_NEW,
                        host_tier_blocks=host_blocks)
    outs = []
    for _ in range(repeats):
        for p in prompts:
            eng.submit(p)
        outs.extend(eng.drain(params))
    eng.sched.check_invariants()
    stats = eng.stats()
    eng.close()
    return {o.rid: o for o in outs}, stats


def run(arch: str = "yi-6b") -> dict:
    cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    prompts = np.random.RandomState(5).randint(
        0, 250, (6, PL)).astype(np.int32)

    # -- A/B 1: readmission cost, recompute vs swap --------------------------
    off, off_st = _serve(cfg, params, prompts, 0)
    on, on_st = _serve(cfg, params, prompts, HOST_BLOCKS)
    assert off_st["preemptions"] > 0, "pool was never starved — bad workload"
    assert on_st["swap_in_blocks"] > 0, "tier never swapped — bad workload"
    for rid in off:         # correctness rides along with the measurement
        assert np.array_equal(np.asarray(off[rid].gen),
                              np.asarray(on[rid].gen)), \
            f"request {rid}: greedy output changed with the host tier on"
    readmit_ratio = off_st["readmit_prefill_tokens"] / max(
        on_st["readmit_prefill_tokens"], 1)

    print(f"swap A/B ({arch}): {len(prompts)} requests, PL {PL}, "
          f"max_new {MAX_NEW}, {SLOTS} slots, {NUM_BLOCKS}-block pool")
    print("tier,preempt_swap,preempt_recompute,readmit_prefill_tok,"
          "swap_out_blk,swap_in_blk")
    print(f"off,{off_st['preempt_swap']},{off_st['preempt_recompute']},"
          f"{off_st['readmit_prefill_tokens']},0,0")
    print(f"on,{on_st['preempt_swap']},{on_st['preempt_recompute']},"
          f"{on_st['readmit_prefill_tokens']},{on_st['swap_out_blocks']},"
          f"{on_st['swap_in_blocks']}")
    print(f"swap re-admission issues {readmit_ratio:.1f}x fewer prefill "
          f"tokens than recompute")
    assert readmit_ratio >= 2, \
        f"swap saved only {readmit_ratio:.1f}x readmission prefill tokens"

    # -- A/B 2: prefix hit-rate, device-only vs tiered index -----------------
    # resubmit the same prompts after the pool churned past them: the
    # device index has been reclaimed, only the host tier still remembers
    _, dev_st = _serve(cfg, params, prompts, 0, repeats=2)
    _, tier_st = _serve(cfg, params, prompts, HOST_BLOCKS, repeats=2)
    hit_gain = tier_st["shared_prefill_tokens"] / max(
        dev_st["shared_prefill_tokens"], 1)
    print(f"\nprefix hit rows over 2 passes: device-only "
          f"{dev_st['shared_prefill_tokens']}, device+host "
          f"{tier_st['shared_prefill_tokens']} ({hit_gain:.1f}x)")
    assert tier_st["shared_prefill_tokens"] > dev_st["shared_prefill_tokens"], \
        "tiered index matched no more rows than the device index alone"

    return {
        "preemptions": off_st["preemptions"],
        "preempt_swap_on": on_st["preempt_swap"],
        "preempt_recompute_off": off_st["preempt_recompute"],
        "readmit_prefill_tokens_recompute": off_st["readmit_prefill_tokens"],
        "readmit_prefill_tokens_swap": on_st["readmit_prefill_tokens"],
        "readmit_ratio": readmit_ratio,
        "swap_out_blocks": on_st["swap_out_blocks"],
        "swap_in_blocks": on_st["swap_in_blocks"],
        "swap_out_bytes": on_st["swap_out_bytes"],
        "swap_in_bytes": on_st["swap_in_bytes"],
        "host_evictions": on_st["swap_host_evictions"],
        "prefix_hit_rows_dev": dev_st["shared_prefill_tokens"],
        "prefix_hit_rows_tiered": tier_st["shared_prefill_tokens"],
        "prefix_hit_gain": hit_gain,
    }


if __name__ == "__main__":
    run()
