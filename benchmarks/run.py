"""Benchmark driver — one section per paper table/figure.

  Table 1  -> bench_dispatch       (sample-flow TCV + dispatch times)
  Figure 7 -> bench_e2e            (end-to-end variant throughput)
  Figure 9 -> bench_linearity      (cluster linearity, TD vs central)
  Figure 10-> bench_reshard_memory (allgather-swap memory release)
  kernels  -> bench_kernels        (fused-kernel micro-benchmarks)
  serving  -> bench_serving        (sync vs continuous-batching generation)
  sampling -> bench_sampling       (deterministic-sampling replay A/B)
  swap     -> bench_swap           (host-tier KV swap vs recompute preemption)
  Table 2  -> bench_partial_stream (partial rollout streams mid-drain)
  Fig. 11  -> bench_moe_scale      (400B-class MoE at production scale)
  roofline -> roofline_table       (renders benchmarks/results/*.json)

Sections whose ``run()`` returns a dict get a machine-readable artifact
``BENCH_<name>.json`` (``{"bench", "elapsed_s", "metrics"}``) written next
to the stdout tables — CI asserts on and uploads these; see
docs/observability.md for the schema.

``PYTHONPATH=src python -m benchmarks.run [section ...] [--out DIR]``
"""
from __future__ import annotations

import argparse
import json
import os
import time

SECTIONS = ["dispatch", "linearity", "reshard_memory", "kernels", "e2e",
            "serving", "sampling", "swap", "partial_stream", "moe_scale",
            "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("sections", nargs="*",
                    help=f"sections to run (default: all): {SECTIONS}")
    ap.add_argument("--out", default=".", metavar="DIR",
                    help="directory for BENCH_<name>.json artifacts")
    args = ap.parse_args()
    bad = [s for s in args.sections if s not in SECTIONS]
    if bad:
        ap.error(f"unknown section(s) {bad}; choose from {SECTIONS}")
    wanted = args.sections or SECTIONS
    for name in wanted:
        mod = __import__(f"benchmarks.bench_{name}"
                         if name != "roofline" else "benchmarks.roofline_table",
                         fromlist=["run"])
        t0 = time.perf_counter()
        print(f"\n{'=' * 70}\n== {name}\n{'=' * 70}")
        result = mod.run()
        dt = time.perf_counter() - t0
        print(f"[{name}: {dt:.1f}s]")
        if isinstance(result, dict):
            path = os.path.join(args.out, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump({"bench": name, "elapsed_s": dt,
                           "metrics": result}, f, indent=1, sort_keys=True)
            print(f"[{name}: wrote {path}]")


if __name__ == "__main__":
    main()
