"""Benchmark driver — one section per paper table/figure.

  Table 1  -> bench_dispatch       (sample-flow TCV + dispatch times)
  Figure 7 -> bench_e2e            (end-to-end variant throughput)
  Figure 9 -> bench_linearity      (cluster linearity, TD vs central)
  Figure 10-> bench_reshard_memory (allgather-swap memory release)
  kernels  -> bench_kernels        (fused-kernel micro-benchmarks)
  serving  -> bench_serving        (sync vs continuous-batching generation)
  Table 2  -> bench_partial_stream (partial rollout streams mid-drain)
  Fig. 11  -> bench_moe_scale      (400B-class MoE at production scale)
  roofline -> roofline_table       (renders benchmarks/results/*.json)

``PYTHONPATH=src python -m benchmarks.run [section ...]``
"""
from __future__ import annotations

import sys
import time

SECTIONS = ["dispatch", "linearity", "reshard_memory", "kernels", "e2e",
            "serving", "partial_stream", "moe_scale", "roofline"]


def main() -> None:
    wanted = sys.argv[1:] or SECTIONS
    for name in wanted:
        mod = __import__(f"benchmarks.bench_{name}"
                         if name != "roofline" else "benchmarks.roofline_table",
                         fromlist=["run"])
        t0 = time.perf_counter()
        print(f"\n{'=' * 70}\n== {name}\n{'=' * 70}")
        mod.run()
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")


if __name__ == "__main__":
    main()
