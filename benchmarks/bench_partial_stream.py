"""Partial rollout over the serving engine: finished samples reach
downstream graph nodes BEFORE the iteration's generation drains.

The workload stages two cohorts so the drain has a long tail: iteration 2
runs 16 carried-over sequences (8 tokens from their response cap left —
they FINISH mid-drain) interleaved with 16 fresh ones (they suspend at the
budget), through 4 serving slots.  With stage fusion on, the executor polls
the dock metadata while the engine drains and dispatches the stream nodes
(ref_inference, reward) the moment finished rows land; with fusion off the
same samples wait for the generation barrier.  The report is the dispatch
timeline of iteration 2 relative to the generation node's completion —
negative lead = streamed before the drain.

``PYTHONPATH=src python -m benchmarks.bench_partial_stream``
"""
from __future__ import annotations

import time


from repro.configs.base import ModelConfig, RLConfig
from repro.core.partial import PartialRolloutTrainer
from repro.data.prompts import PromptDataset, pattern_task

TINY = ModelConfig(
    name="tiny", arch_type="dense", num_layers=2, d_model=128,
    vocab_size=512, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
    dtype="float32", remat=False)

BUDGET = 8
STREAM_NODES = ("ref_inference", "reward")


def _instrument(tr):
    """Wrap every graph node's fn to log (name, start_t, end_t, n_samples)."""
    events = []

    def make(name, orig):
        def wrapped(ctx, io):
            t0 = time.perf_counter()
            out = orig(ctx, io)
            events.append((name, t0, time.perf_counter(), len(io.idxs)))
            return out
        return wrapped

    for node in tr.graph.nodes:
        node.fn = make(node.name, node.fn)
    return events


def _trainer(stage_fusion: bool) -> PartialRolloutTrainer:
    rl = RLConfig(num_generations=2, max_prompt_len=12, max_response_len=16,
                  lr=1e-4, greedy=True, partial_rollout=True,
                  stage_fusion=stage_fusion, serve_max_slots=4,
                  serve_block_size=4)
    ds = PromptDataset(pattern_task(), max_prompt_len=12, seed=0)
    return PartialRolloutTrainer(TINY, rl, ds, budget=BUDGET, num_nodes=4,
                                 seed=0)


def _measure(stage_fusion: bool):
    tr = _trainer(stage_fusion)
    tr.iteration(global_batch=8)          # warmup + creates the carryovers
    events = _instrument(tr)
    tr.iteration(global_batch=8)          # measured: mixed finish/suspend
    gen_end = next(e[2] for e in events if e[0] == "actor_generation")
    streamed = [(n, t0 - gen_end, k) for n, t0, _, k in events
                if n in STREAM_NODES]
    return tr, gen_end, streamed, events


def run():
    print(f"partial rollout, budget {BUDGET}, 4 slots, cohorts 16+16 "
          f"(carried finish mid-drain, fresh suspend)\n")
    for fusion in (True, False):
        tr, gen_end, streamed, events = _measure(fusion)
        pre = [(n, dt, k) for n, dt, k in streamed if dt < 0]
        label = "fusion on (streaming)" if fusion else "fusion off (barrier)"
        print(f"-- {label} --")
        for n, dt, k in sorted(streamed, key=lambda e: e[1]):
            when = "BEFORE drain" if dt < 0 else "after drain"
            print(f"  {n:<14} {k:>2} samples at gen_end{dt:+.3f}s ({when})")
        npre = sum(k for _, _, k in pre)
        print(f"  => {npre} samples reached downstream nodes before "
              f"generation drained, pending={tr.pending_partials}\n")
        if fusion:
            assert pre, ("no stream dispatch preceded the generation drain "
                         "with fusion on")
        else:
            assert not pre
    print("acceptance: finished samples stream to downstream nodes "
          "mid-drain (fusion on), and only there")


if __name__ == "__main__":
    run()
