"""Paper Figure 10 — memory profiling of the resharding flow.

(a) analytic, at production scale: qwen2.5-32b resharded TP8DP2 -> TP4DP4
    (the paper's exact case) — per-device timeline with and without
    allgather-swap; the released redundancy should be ~8 GB/device.
(b) measured, at smoke scale: the real Resharder on this container, ledger
    timelines for both strategies.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.resharding import Resharder
from repro.launch.mesh import make_mesh
from repro.launch.specs import params_structs
from repro.models.model import build_model
from repro.sharding import param_specs


def analytic_qwen32b():
    """Per-device bytes for the paper's TP8DP2 -> TP4DP4 case on 16 devices."""
    cfg = get_config("qwen2.5-32b")
    ps = params_structs(cfg)
    total = sum(np.prod(l.shape) * 2 for l in jax.tree.leaves(ps))  # bf16
    upd_per_dev = total / 8          # TP8 (weights replicated across DP)
    gen_per_dev = total / 4          # TP4
    print("# Figure 10 — resharding memory (qwen2.5-32b, TP8DP2 -> TP4DP4)")
    print(f"total weights: {total/2**30:.1f} GiB")
    print("strategy,event,per_device_GiB")
    rows = []
    for strategy in ("naive", "allgather_swap"):
        timeline = [("update resident", upd_per_dev)]
        if strategy == "naive":
            timeline.append(("gen materialized",
                             upd_per_dev + gen_per_dev))
            timeline.append(("generation stage", upd_per_dev + gen_per_dev))
        else:
            timeline.append(("gen materialized",
                             upd_per_dev + gen_per_dev))
            timeline.append(("update swapped D2H", gen_per_dev))
        for ev, b in timeline:
            print(f"{strategy},{ev},{b/2**30:.2f}")
            rows.append((strategy, ev, b))
    released = upd_per_dev
    print(f"released by allgather-swap: {released/2**30:.2f} GiB/device "
          f"(paper reports ~8 GB)")
    return rows


def measured_smoke(arch: str = "qwen2.5-32b"):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    mesh = make_mesh((1, 1), ("data", "model"))
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    t = param_specs(cfg, params, mesh, stage="train")
    g = param_specs(cfg, params, mesh, stage="gen", gen_mode="tp")
    print("strategy,peak_MB,end_MB,d2h_MB,swap_time_modeled_ms")
    out = []
    for swap in (False, True):
        rs = Resharder(mesh, t, g, use_swap=swap)
        _, stash, led = rs.to_generation(params)
        name = "allgather_swap" if swap else "naive"
        end = led.timeline()[-1][1]
        print(f"{name},{led.peak_bytes/1e6:.1f},{end/1e6:.1f},"
              f"{led.d2h_bytes/1e6:.1f},{led.swap_time_s*1e3:.2f}")
        out.append((name, led.snapshot()))
    return out


def run():
    rows = analytic_qwen32b()
    rows_m = measured_smoke()
    return rows + rows_m


if __name__ == "__main__":
    run()
