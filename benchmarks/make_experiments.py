"""Regenerate the data-driven tables inside EXPERIMENTS.md from
benchmarks/results/*.json (keeps the narrative sections intact by rewriting
only the blocks between the AUTOGEN markers — or, with --full, rewrites the
whole §Roofline chapter)."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.roofline_table import fmt_table, load  # noqa: E402


def maxterm(r):
    return max(r["compute_s"], r["memory_s"], r["collective_s"])


def tables() -> dict:
    base = load("16x16", None)
    opt = load("16x16", "opt")
    multi_opt = load("2x16x16", "opt")
    base_d = {(r["arch"], r["shape"]): r for r in base
              if r.get("status") == "ok"}
    opt_d = {(r["arch"], r["shape"]): r for r in opt
             if r.get("status") == "ok"}
    mo_d = {(r["arch"], r["shape"]): r for r in multi_opt
            if r.get("status") == "ok"}

    delta = ["| arch | shape | base dominant | base max s | opt dominant | "
             "opt max s | speedup |", "|---|---|---|---|---|---|---|"]
    for k in sorted(base_d):
        if k not in opt_d:
            continue
        b, o = base_d[k], opt_d[k]
        bm, om = maxterm(b), maxterm(o)
        delta.append(f"| {k[0]} | {k[1]} | {b['dominant']} | {bm:.2f} | "
                     f"{o['dominant']} | {om:.2f} | "
                     f"{bm / max(om, 1e-9):.2f}x |")

    pods = ["| arch | shape | 256-chip s | 512-chip s | scaling |",
            "|---|---|---|---|---|"]
    for k in sorted(opt_d):
        if k not in mo_d:
            continue
        o, m = opt_d[k], mo_d[k]
        pods.append(f"| {k[0]} | {k[1]} | {maxterm(o):.2f} | "
                    f"{maxterm(m):.2f} | "
                    f"{maxterm(o) / max(maxterm(m), 1e-9):.2f}x |")

    return {
        "base_table": fmt_table(base),
        "opt_table": fmt_table(opt),
        "delta_table": "\n".join(delta),
        "pod_table": "\n".join(pods),
    }


def run():
    t = tables()
    for name, content in t.items():
        path = os.path.join(os.path.dirname(__file__), "results",
                            f"_{name}.md")
        with open(path, "w") as f:
            f.write(content)
        print(f"wrote {path} ({len(content.splitlines())} lines)")
    return t


if __name__ == "__main__":
    run()
