"""Paper Figure 9 — cluster linearity of the sample flow.

64 prompts per node, scaling 1→24 nodes; dispatch wall-time modeled through
the real dock ledger (max per-warehouse link load).  Linearity = throughput
at N nodes / (N × throughput at 1 node), where sample-flow time is the
dock's simulated dispatch plus a fixed per-node compute time (the compute
scales perfectly; dispatch is what breaks linearity — the paper's point).

Variants: MSRL (one warehouse per node), MSRLB (central replay buffer but
distributed controllers), VeRL-like (central buffer + central controller).
"""
from __future__ import annotations

import numpy as np

from repro.core.transfer_dock import (CentralReplayBuffer, DispatchLedger,
                                      TransferDock)

PROMPTS_PER_NODE = 64
N_GEN = 8
ROW_BYTES = 4 * (2048 + 5 * 8192)      # Eq. (1) per-sample payload, B=4
COMPUTE_S = 30.0                        # per-iteration compute (perfectly DP)


def _states(nodes: int) -> dict:
    return {"actor_generation": 0, "actor_inference": 0,
            "ref_inference": 1 % nodes, "reward": 2 % nodes,
            "actor_update": 0}


def _simulate(dock, nodes: int) -> float:
    """Workers are data-parallel across ALL nodes (each node's actor shard
    produces and consumes its 1/nodes slice) — the Fig 2 pipeline."""
    n = PROMPTS_PER_NODE * nodes * N_GEN
    rows = np.zeros((n, ROW_BYTES // 4), np.float32)
    per = n // nodes
    slices = [(list(range(i * per, (i + 1) * per)), i) for i in range(nodes)]
    for idxs, node in slices:                       # generation writes
        dock.put("tokens", idxs, rows[:per], src_node=node)
    for state in ("actor_inference", "ref_inference", "reward"):
        for idxs, node in slices:                   # three readers
            dock.get(state, "tokens", idxs, dst_node=node)
    for idxs, node in slices:                       # inference writes
        dock.put("old_logp", idxs, rows[:per], src_node=node)
    for idxs, node in slices:                       # update reads
        dock.get("actor_update", "tokens", idxs, dst_node=node)
        dock.get("actor_update", "old_logp", idxs, dst_node=node)
    return dock.ledger.simulated_dispatch_time


def run(max_nodes: int = 24):
    print("# Figure 9 — linearity (throughput_N / (N * throughput_1))")
    print("nodes,MSRL,MSRLB,VeRL-like")
    base = {}
    out = []
    for nodes in (1, 2, 4, 8, 16, 24):
        if nodes > max_nodes:
            break
        res = {}
        for name in ("MSRL", "MSRLB", "VeRL-like"):
            if name == "MSRL":
                dock = TransferDock(nodes, _states(nodes), DispatchLedger())
            elif name == "MSRLB":
                dock = TransferDock(1, _states(nodes), DispatchLedger())
            else:
                dock = CentralReplayBuffer(_states(nodes), DispatchLedger())
            dt = _simulate(dock, nodes)
            # throughput ∝ tokens / (compute + dispatch); tokens ∝ nodes
            tput = nodes * PROMPTS_PER_NODE * N_GEN / (COMPUTE_S + dt)
            res[name] = tput
        if not base:
            base = dict(res)
        lin = {k: res[k] / (nodes * base[k]) for k in res}
        print(f"{nodes},{lin['MSRL']:.3f},{lin['MSRLB']:.3f},"
              f"{lin['VeRL-like']:.3f}")
        out.append((nodes, lin))
    return out


if __name__ == "__main__":
    run()
