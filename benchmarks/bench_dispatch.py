"""Paper Table 1 — TCV (GB) and dispatch times for the sample flow, plus the
transfer-dock Eq. (4) volumes, and a MEASURED serialization pass through the
real TransferDock at a reduced scale."""
from __future__ import annotations

import time

import numpy as np

from repro.core.transfer_dock import (DispatchLedger, TransferDock, cv_gb,
                                      dispatch_time_s, tcv_gb, tcv_td_gb)

TABLE1_ROWS = [
    # G, N, PL, n, SL, M    (B=4 per the paper)
    (256, 8, 2048, 5, 8192, 3),
    (256, 16, 2048, 5, 16384, 3),
    (1024, 16, 2048, 5, 16384, 3),
    (1024, 32, 4096, 8, 32768, 5),
    (4096, 32, 4096, 8, 32768, 5),
    (8192, 64, 4096, 8, 65536, 5),
]


def analytic_table(C: int = 5, S: int = 16):
    rows = []
    for G, N, PL, n, SL, M in TABLE1_ROWS:
        tcv = tcv_gb(G, N, 4, PL, n, SL, M)
        rows.append({
            "G": G, "N": N, "PL": PL, "n": n, "SL": SL, "M": M,
            "CV_GB": cv_gb(G, N, 4, PL, n, SL, M),
            "TCV_GB": tcv,
            "T100_s": dispatch_time_s(tcv, 100 * 1024 ** 2),
            "T1K_s": dispatch_time_s(tcv, 1024 ** 3),
            "TCV_TD_GB": tcv_td_gb(G, N, 4, PL, n, SL, M, C, S),
            "T100_TD_s": dispatch_time_s(
                tcv_td_gb(G, N, 4, PL, n, SL, M, C, S), 100 * 1024 ** 2),
        })
    return rows


def measured_dock_pass(n_samples: int = 256, row_bytes: int = 1 << 16,
                       S: int = 8):
    """Wall-time of a real put+get cycle through the dock (numpy data plane)."""
    states = {"u": 0}
    dock = TransferDock(S, states, DispatchLedger())
    rows = np.zeros((n_samples, row_bytes // 4), np.float32)
    t0 = time.perf_counter()
    dock.put("x", list(range(n_samples)), rows, src_node=1)
    _ = dock.get("u", "x", list(range(n_samples)), dst_node=1)
    wall = time.perf_counter() - t0
    return {
        "n_samples": n_samples, "row_bytes": row_bytes, "S": S,
        "wall_s": wall,
        "simulated_s": dock.ledger.simulated_dispatch_time,
        "moved_bytes": dock.ledger.internode_bytes,
    }


def run():
    out = []
    print("# Table 1 — sample-flow volume & dispatch time "
          "(central vs transfer dock, C=5, S=16)")
    print("G,N,PL,SL,TCV_GB,T100_s,T1K_s,TCV_TD_GB,T100_TD_s,speedup")
    for r in analytic_table():
        sp = r["T100_s"] / max(r["T100_TD_s"], 1e-12)
        print(f"{r['G']},{r['N']},{r['PL']},{r['SL']},{r['TCV_GB']:.2f},"
              f"{r['T100_s']:.1f},{r['T1K_s']:.2f},{r['TCV_TD_GB']:.4f},"
              f"{r['T100_TD_s']:.2f},{sp:.1f}x")
        out.append(("table1", r))
    m = measured_dock_pass()
    print(f"measured dock pass: {m['moved_bytes']/1e6:.1f} MB in "
          f"{m['wall_s']*1e3:.1f} ms wall (simulated internode: "
          f"{m['simulated_s']:.3f} s)")
    return out


if __name__ == "__main__":
    run()
