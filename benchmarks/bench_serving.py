"""Serving: synchronized batch decode vs continuous batching.

The workload is the long-tail shape the paper's partial-rollout machinery
targets: most requests want a handful of tokens, a few want many.  The
synchronized ``RolloutEngine`` serves it in waves of ``slots`` requests —
every sequence in a wave decodes until the SLOWEST one finishes, so short
requests burn slot-steps idling.  The ``ServingEngine`` evicts each sequence
the moment it completes and refills the slot from the queue, so the same
slot count produces tokens the whole time.

Both paths are warmed up (compile) before timing.  Also asserts the
acceptance property: under greedy decoding with a uniform budget,
``ServingEngine.generate`` reproduces ``RolloutEngine`` token-for-token.

``PYTHONPATH=src python -m benchmarks.bench_serving``
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.rollout import RolloutEngine
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve.engine import ServingEngine

PL = 16            # prompt length
SLOTS = 8
BLOCK = 16
# skewed budgets: 3/4 short, a long tail — shuffled into arrival order so
# every synchronized wave gets stuck behind at least one long request
BUDGETS = [6] * 24 + [24] * 4 + [48] * 4
MAX_NEW = max(BUDGETS)


def _workload(seed: int = 0):
    rng = np.random.RandomState(seed)
    budgets = np.array(BUDGETS)
    rng.shuffle(budgets)
    prompts = rng.randint(0, 250, (len(budgets), PL)).astype(np.int32)
    return prompts, budgets


def _sync_serve(engine: RolloutEngine, params, prompts, budgets, key):
    """Waves of SLOTS requests; each wave decodes to its own longest budget.
    Returns (useful_tokens, wave-end latency per request)."""
    useful, lats = 0, []
    t0 = time.perf_counter()
    for lo in range(0, len(prompts), SLOTS):
        wave_b = budgets[lo:lo + SLOTS]
        engine.max_new = int(wave_b.max())
        key, k = jax.random.split(key)
        res = engine.generate(params, prompts[lo:lo + SLOTS], k)
        # tokens beyond a request's own budget are wasted slot-steps
        useful += int(np.minimum(res.lengths, wave_b).sum())
        lats.extend([time.perf_counter() - t0] * len(wave_b))
    return useful, time.perf_counter() - t0, lats


def _cont_serve(engine: ServingEngine, params, prompts, budgets):
    t0 = time.perf_counter()
    for p, b in zip(prompts, budgets):
        engine.submit(p, max_new=int(b))
    outs = engine.drain(params)
    dt = time.perf_counter() - t0
    return sum(len(o.gen) for o in outs), dt, [o.latency_s for o in outs]


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(int(q * len(xs)), len(xs) - 1)]


def run(arch: str = "yi-6b"):
    cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
    tok = ByteTokenizer()
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    prompts, budgets = _workload()

    sync = RolloutEngine(cfg, max_new=MAX_NEW, eos_id=tok.eos_id,
                         pad_id=tok.pad_id, greedy=True)
    cont = ServingEngine(cfg, max_new=MAX_NEW, eos_id=tok.eos_id,
                         pad_id=tok.pad_id, greedy=True, max_slots=SLOTS,
                         block_size=BLOCK, max_seq_len=PL + MAX_NEW)

    # -- acceptance property: greedy bit-compatibility -----------------------
    res_a = sync.generate(params, prompts[:SLOTS], jax.random.PRNGKey(7))
    sync.max_new = MAX_NEW
    res_b = cont.generate(params, prompts[:SLOTS], jax.random.PRNGKey(7))
    match = (np.array_equal(res_a.tokens, res_b.tokens)
             and np.array_equal(res_a.response_mask, res_b.response_mask))
    print(f"greedy output match (serving == sync): {match}")
    assert match, "ServingEngine diverged from RolloutEngine under greedy"

    # -- warmup (compiles), then timed pass ----------------------------------
    _sync_serve(sync, params, prompts, budgets, jax.random.PRNGKey(1))
    _cont_serve(cont, params, prompts, budgets)
    s_tok, s_dt, s_lat = _sync_serve(sync, params, prompts, budgets,
                                     jax.random.PRNGKey(2))
    c_tok, c_dt, c_lat = _cont_serve(cont, params, prompts, budgets)

    print(f"\n{len(prompts)} requests, budgets "
          f"{sorted(set(BUDGETS))} (skewed), {SLOTS} slots")
    print("engine,tok,wall_s,tok_per_s,p50_ms,p99_ms")
    print(f"synchronized,{s_tok},{s_dt:.2f},{s_tok / s_dt:.1f},"
          f"{_pct(s_lat, .5) * 1e3:.0f},{_pct(s_lat, .99) * 1e3:.0f}")
    print(f"continuous,{c_tok},{c_dt:.2f},{c_tok / c_dt:.1f},"
          f"{_pct(c_lat, .5) * 1e3:.0f},{_pct(c_lat, .99) * 1e3:.0f}")
    speedup = (c_tok / c_dt) / (s_tok / s_dt)
    print(f"continuous-batching speedup: {speedup:.2f}x tok/s")
    return speedup


if __name__ == "__main__":
    run()
