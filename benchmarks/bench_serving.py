"""Serving: synchronized batch decode vs continuous batching.

The workload is the long-tail shape the paper's partial-rollout machinery
targets: most requests want a handful of tokens, a few want many.  The
synchronized ``RolloutEngine`` serves it in waves of ``slots`` requests —
every sequence in a wave decodes until the SLOWEST one finishes, so short
requests burn slot-steps idling.  The ``ServingEngine`` evicts each sequence
the moment it completes and refills the slot from the queue, so the same
slot count produces tokens the whole time.

Both paths are warmed up (compile) before timing.  Also asserts the
acceptance property: under greedy decoding with a uniform budget,
``ServingEngine.generate`` reproduces ``RolloutEngine`` token-for-token.

The second section is the DECODE-PATH A/B: one fused decode step via the old
dense-gather (``gather_kv`` + dense ``decode`` + row re-extraction — rebuilt
here as the baseline; the engine no longer contains it) versus the paged
decode attention the engine now runs, at FIXED live tokens while
``max_blocks_per_seq`` grows.  Dense-gather cost scales with pool capacity;
paged cost must stay ~flat.

The third section is the PREFIX-CACHE A/B on a GRPO-shaped workload (N
rollouts per prompt): admitted-prefill tokens with ref-counted prompt-head
block sharing on vs off.  Shared must beat unshared by >= 4x on this
workload; it also smoke-checks the chunked-prefill step budget (no engine
step spends more than ``prefill_chunk`` prefill tokens even when a
max-length prompt is admitted mid-decode).

``PYTHONPATH=src python -m benchmarks.bench_serving [decode|prefix]``
(``decode`` / ``prefix`` run only that A/B — the CI smoke steps.)
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.rollout import RolloutEngine, sample_tokens
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve.engine import ServingEngine
from repro.serve.paged_cache import PagedKVCache, gather_kv, scatter_token

PL = 16            # prompt length
SLOTS = 8
BLOCK = 16
# skewed budgets: 3/4 short, a long tail — shuffled into arrival order so
# every synchronized wave gets stuck behind at least one long request
BUDGETS = [6] * 24 + [24] * 4 + [48] * 4
MAX_NEW = max(BUDGETS)


def _workload(seed: int = 0):
    rng = np.random.RandomState(seed)
    budgets = np.array(BUDGETS)
    rng.shuffle(budgets)
    prompts = rng.randint(0, 250, (len(budgets), PL)).astype(np.int32)
    return prompts, budgets


def _sync_serve(engine: RolloutEngine, params, prompts, budgets, key):
    """Waves of SLOTS requests; each wave decodes to its own longest budget.
    Returns (useful_tokens, wave-end latency per request)."""
    useful, lats = 0, []
    t0 = time.perf_counter()
    for lo in range(0, len(prompts), SLOTS):
        wave_b = budgets[lo:lo + SLOTS]
        engine.max_new = int(wave_b.max())
        key, k = jax.random.split(key)
        res = engine.generate(params, prompts[lo:lo + SLOTS], k)
        # tokens beyond a request's own budget are wasted slot-steps
        useful += int(np.minimum(res.lengths, wave_b).sum())
        lats.extend([time.perf_counter() - t0] * len(wave_b))
    return useful, time.perf_counter() - t0, lats


def _cont_serve(engine: ServingEngine, params, prompts, budgets):
    t0 = time.perf_counter()
    for p, b in zip(prompts, budgets):
        engine.submit(p, max_new=int(b))
    outs = engine.drain(params)
    dt = time.perf_counter() - t0
    return sum(len(o.gen) for o in outs), dt, [o.latency_s for o in outs]


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(int(q * len(xs)), len(xs) - 1)]


def run(arch: str = "yi-6b"):
    cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
    tok = ByteTokenizer()
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    prompts, budgets = _workload()

    sync = RolloutEngine(cfg, max_new=MAX_NEW, eos_id=tok.eos_id,
                         pad_id=tok.pad_id, greedy=True)
    # prefix cache OFF here: the timed pass re-submits the warmup's prompts,
    # and a warm prefix cache would fold its own win into the continuous-
    # batching number — this section measures eviction/refill alone (the
    # sharing win is measured by prefix_ab below)
    cont = ServingEngine(cfg, max_new=MAX_NEW, eos_id=tok.eos_id,
                         pad_id=tok.pad_id, greedy=True, max_slots=SLOTS,
                         block_size=BLOCK, max_seq_len=PL + MAX_NEW,
                         prefix_cache=False)

    # -- acceptance property: greedy bit-compatibility -----------------------
    res_a = sync.generate(params, prompts[:SLOTS], jax.random.PRNGKey(7))
    sync.max_new = MAX_NEW
    res_b = cont.generate(params, prompts[:SLOTS], jax.random.PRNGKey(7))
    match = (np.array_equal(res_a.tokens, res_b.tokens)
             and np.array_equal(res_a.response_mask, res_b.response_mask))
    print(f"greedy output match (serving == sync): {match}")
    assert match, "ServingEngine diverged from RolloutEngine under greedy"

    # -- warmup (compiles), then timed pass ----------------------------------
    _sync_serve(sync, params, prompts, budgets, jax.random.PRNGKey(1))
    _cont_serve(cont, params, prompts, budgets)
    s_tok, s_dt, s_lat = _sync_serve(sync, params, prompts, budgets,
                                     jax.random.PRNGKey(2))
    c_tok, c_dt, c_lat = _cont_serve(cont, params, prompts, budgets)

    print(f"\n{len(prompts)} requests, budgets "
          f"{sorted(set(BUDGETS))} (skewed), {SLOTS} slots")
    print("engine,tok,wall_s,tok_per_s,p50_ms,p99_ms")
    print(f"synchronized,{s_tok},{s_dt:.2f},{s_tok / s_dt:.1f},"
          f"{_pct(s_lat, .5) * 1e3:.0f},{_pct(s_lat, .99) * 1e3:.0f}")
    print(f"continuous,{c_tok},{c_dt:.2f},{c_tok / c_dt:.1f},"
          f"{_pct(c_lat, .5) * 1e3:.0f},{_pct(c_lat, .99) * 1e3:.0f}")
    speedup = (c_tok / c_dt) / (s_tok / s_dt)
    print(f"continuous-batching speedup: {speedup:.2f}x tok/s")
    p_growth = decode_ab(arch)
    prefix_ratio = prefix_ab(arch)
    # machine-readable artifact (benchmarks.run writes BENCH_serving.json);
    # engine counters come from the metrics registry so the artifact and
    # the stdout table cannot drift apart
    st = cont.stats()
    return {
        "tok_s": c_tok / c_dt,
        "sync_tok_s": s_tok / s_dt,
        "speedup_vs_sync": speedup,
        "latency_p50_s": _pct(c_lat, .5),
        "latency_p99_s": _pct(c_lat, .99),
        "ttft_p50_s": st["ttft_s"].get("p50", 0.0),
        "ttft_p95_s": st["ttft_s"].get("p95", 0.0),
        "steps": st["steps"],
        "prefill_tokens": st["prefill_tokens"],
        "shared_prefill_tokens": st["shared_prefill_tokens"],
        "decode_tokens": st["decode_tokens"],
        "preemptions": st["preemptions"],
        "decode_paged_growth": p_growth,
        "prefix_cache_ratio": prefix_ratio,
    }


def _time_step(fn, state, iters: int) -> float:
    """Median ms over ``iters`` calls of a (pool_k, pool_v)-carrying step."""
    pool_k, pool_v, rest = state
    for _ in range(3):                                   # compile + warm
        pool_k, pool_v, nxt, _ = fn(pool_k, pool_v, *rest)
        jax.block_until_ready(nxt)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        pool_k, pool_v, nxt, _ = fn(pool_k, pool_v, *rest)
        jax.block_until_ready(nxt)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


def decode_ab(arch: str = "yi-6b", live: int = 48, slots: int = 16,
              bs: int = 16, mb_list=(4, 8, 16), iters: int = 30) -> float:
    """Decode-step latency, dense-gather vs paged attention, at ``live``
    cached tokens per slot while max_blocks_per_seq sweeps ``mb_list``.
    Returns paged growth factor over the sweep (dense's scales with MB)."""
    cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
    tok = ByteTokenizer()
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    assert live < mb_list[0] * bs, "live tokens must fit the smallest pool"

    # ONE pool size for the whole sweep (only max_blocks_per_seq grows):
    # keeps the per-step KV scatter cost constant — XLA CPU ignores buffer
    # donation, so pool-sized copies would otherwise pollute the scaling
    num_blocks = slots * mb_list[-1]

    def make_state(mb):
        cache = PagedKVCache(cfg, num_blocks=num_blocks, block_size=bs,
                             max_blocks_per_seq=mb)
        # slot i owns blocks [i*max_mb, i*max_mb + mb); random KV in the pool
        tables = (np.arange(slots, dtype=np.int32)[:, None] * mb_list[-1]
                  + np.arange(mb, dtype=np.int32)[None, :])
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        cache.pool_k = jax.random.normal(k1, cache.pool_k.shape,
                                         cache.pool_k.dtype)
        cache.pool_v = jax.random.normal(k2, cache.pool_v.shape,
                                         cache.pool_v.dtype)
        tok_in = np.full((slots, 1), 7, np.int32)
        pos = np.full((slots,), live, np.int32)
        done = np.zeros((slots,), bool)
        rest = (jnp.asarray(tables), jnp.asarray(tok_in), jnp.asarray(pos),
                jnp.asarray(done), jax.random.PRNGKey(2))
        return cache.pool_k, cache.pool_v, rest

    def paged_step(pool_k, pool_v, tables, t, pos, done, key):
        logits, new_k, new_v = model.decode_paged(
            params, cfg, pool_k, pool_v, tables, t, pos, block_size=bs)
        rows = jnp.arange(tables.shape[0])
        flat = tables[rows, pos // bs] * bs + pos % bs
        pool_k = scatter_token(pool_k, new_k, flat)
        pool_v = scatter_token(pool_v, new_v, flat)
        nxt, lp = sample_tokens(logits, key, temperature=1.0, greedy=True,
                                done=done, pad_id=tok.pad_id)
        return pool_k, pool_v, nxt, lp

    def dense_step(pool_k, pool_v, tables, t, pos, done, key):
        # the retired hot loop: gather the WHOLE pool to a dense per-slot
        # view, dense decode, re-extract the written rows
        cache = gather_kv(pool_k, pool_v, tables, bs)
        logits, cache = model.decode(params, cfg, cache, t, pos)
        rows = jnp.arange(tables.shape[0])
        wk = cache["k"][:, rows, pos]
        wv = cache["v"][:, rows, pos]
        flat = tables[rows, pos // bs] * bs + pos % bs
        pool_k = scatter_token(pool_k, wk, flat)
        pool_v = scatter_token(pool_v, wv, flat)
        nxt, lp = sample_tokens(logits, key, temperature=1.0, greedy=True,
                                done=done, pad_id=tok.pad_id)
        return pool_k, pool_v, nxt, lp

    paged = jax.jit(paged_step, donate_argnums=(0, 1))
    dense = jax.jit(dense_step, donate_argnums=(0, 1))

    print(f"\ndecode-step A/B ({arch}): {live} live tokens/slot, "
          f"{slots} slots, block_size {bs}")
    print("max_blocks_per_seq,capacity_tokens,dense_ms,paged_ms")
    rows = []
    for mb in mb_list:
        d = _time_step(dense, make_state(mb), iters)
        p = _time_step(paged, make_state(mb), iters)
        rows.append((mb, d, p))
        print(f"{mb},{mb * bs},{d:.3f},{p:.3f}")
    d_growth = rows[-1][1] / rows[0][1]
    p_growth = rows[-1][2] / rows[0][2]
    span = mb_list[-1] / mb_list[0]
    print(f"capacity grew {span:.0f}x: dense-gather step {d_growth:.2f}x, "
          f"paged step {p_growth:.2f}x (flat is the win)")
    # CPU timing is noisy; the robust properties are (a) at the largest
    # capacity the paged step beats the dense gather outright and (b) paged
    # growth stays well under the capacity span
    assert rows[-1][2] < rows[-1][1], \
        "paged decode step slower than the dense gather at max capacity"
    assert p_growth < span / 2, \
        "paged decode step scaled with capacity like the dense gather"
    return p_growth


def prefix_ab(arch: str = "yi-6b", groups: int = 4, n: int = 8,
              pl: int = 33, bs: int = 8, max_new: int = 6,
              chunk: int = 8) -> float:
    """Admitted-prefill tokens on a GRPO-shaped workload (``groups`` prompts
    x ``n`` rollouts each), prefix-cache block sharing ON vs OFF.  With
    sharing, the block-aligned prompt head is prefilled once per group and
    every other member prefills only the divergent tail, so the ratio
    approaches pl / tail.  Also asserts the chunked-prefill step budget: a
    max-length prompt admitted while slots are mid-decode never pushes one
    step's prefill work past ``prefill_chunk`` tokens."""
    cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
    tok = ByteTokenizer()
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    prompts = rng.randint(0, 250, (groups, pl)).astype(np.int32)

    def serve(prefix_cache: bool) -> ServingEngine:
        eng = ServingEngine(cfg, max_new=max_new, eos_id=tok.eos_id,
                            pad_id=tok.pad_id, greedy=True, max_slots=8,
                            block_size=bs, max_seq_len=pl + max_new,
                            prefix_cache=prefix_cache, prefill_chunk=chunk)
        for g in range(groups):
            for _ in range(n):
                eng.submit(prompts[g])
        eng.drain(params)
        eng.sched.check_invariants()
        return eng

    unshared = serve(False)
    shared = serve(True)
    ratio = unshared.prefill_tokens / shared.prefill_tokens
    print(f"\nprefix-cache A/B ({arch}): {groups} prompts x {n} rollouts, "
          f"PL {pl}, block {bs}, chunk {chunk}")
    print("mode,admitted_prefill_tokens,shared_rows")
    print(f"unshared,{unshared.prefill_tokens},0")
    print(f"shared,{shared.prefill_tokens},{shared.shared_prefill_tokens}")
    print(f"shared-prompt GRPO workload: {ratio:.1f}x fewer admitted-prefill "
          f"tokens with block sharing")
    assert ratio >= 4, \
        f"prefix sharing saved only {ratio:.1f}x admitted-prefill tokens"
    assert shared.max_step_prefill <= chunk and \
        unshared.max_step_prefill <= chunk, "chunk budget exceeded"

    # chunk budget under a max-length admission mid-decode
    eng = ServingEngine(cfg, max_new=max_new, eos_id=tok.eos_id,
                        pad_id=tok.pad_id, greedy=True, max_slots=2,
                        block_size=bs, max_seq_len=pl + max_new,
                        prefill_chunk=chunk)
    eng.submit(prompts[0][:8])
    eng.step(params)                   # short request decoding
    eng.submit(prompts[1])             # max-length prompt lands mid-decode
    eng.drain(params)
    assert eng.max_step_prefill <= chunk, \
        f"step spent {eng.max_step_prefill} prefill tokens > chunk {chunk}"
    print(f"max prefill tokens in any step: {eng.max_step_prefill} "
          f"(budget {chunk})")
    return ratio


if __name__ == "__main__":
    if "decode" in sys.argv[1:]:
        decode_ab()
    elif "prefix" in sys.argv[1:]:
        prefix_ab()
    else:
        run()
