"""Render the §Roofline table from benchmarks/results/*.json (written by
repro.launch.dryrun)."""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "16x16", tag: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if len(parts) == 3:
            a, s, m = parts
            t = ""
        elif len(parts) == 4:
            a, s, m, t = parts
        else:
            continue
        if m != mesh or (tag or "") != t:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_table(recs, include_ideal: bool = True) -> str:
    recs = [r for r in recs if r.get("shape") in SHAPE_ORDER]
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    hdr = ("| arch | shape | compute_s | memory_s | mem_ideal_s | coll_s | "
           "dominant | useful | args_GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"SKIP | — | — |")
            continue
        args_gb = r.get("memory_stats", {}).get("argument_bytes", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.2f} | {r.get('memory_ideal_s', 0):.3f} | "
            f"{r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {args_gb:.2f} |")
    return hdr + "\n".join(lines)


def run():
    for mesh in ("16x16", "2x16x16"):
        recs = load(mesh)
        if not recs:
            continue
        print(f"\n# Roofline — mesh {mesh} ({len(recs)} pairs)")
        print(fmt_table(recs))
    return True


if __name__ == "__main__":
    run()
