"""Paper §"Results of Large-scale MoE Models" (Fig. 11) — the 400B-class MoE
at production scale, from the compiled dry-run records.

The paper trains DeepSeek-R1-671B on 384 NPUs with stage-specific layouts
(TP4PP6EP16DP2 update / TP2PP1EP64DP6 generation).  Our analogue is
llama4-maverick-400b-a17b on the 256/512-chip meshes with EP16+FSDP update
layout and the EP generation layout, plus the resharding-flow collective
schedule between them.  This section reads the dry-run JSONs and reports the
per-stage roofline + the modeled end-to-end tokens/s/device (Eq. 5 with the
roofline max-terms standing in for stage times).
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")
ARCH = "llama4-maverick-400b-a17b"


def _rec(shape: str, mesh: str, tag: str = "opt"):
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(RESULTS, f"{ARCH}__{shape}__{mesh}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run():
    print(f"# Large-scale MoE ({ARCH}) — per-device roofline terms (s)")
    print("mesh,shape,compute,memory,collective,dominant,args_GB")
    for mesh in ("16x16", "2x16x16"):
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            r = _rec(shape, mesh)
            if not r:
                continue
            args_gb = r["memory_stats"]["argument_bytes"] / 2 ** 30
            print(f"{mesh},{shape},{r['compute_s']:.2f},{r['memory_s']:.2f},"
                  f"{r['collective_s']:.2f},{r['dominant']},{args_gb:.1f}")

    # Roofline UPPER BOUND on Eq.-5 throughput for the paper's Fig.-11
    # setting (G=384, N=32, PL=1K, SL=2K) on 512 chips — analytic terms
    # (active-path compute + KV-cache traffic), i.e. zero bubbles, no
    # long-tail, perfect overlap.
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs import get_config
    from repro.launch.analysis import TPU_V5E, active_params

    cfg = get_config(ARCH)
    G, N, PL, SL, ND = 384, 32, 1024, 2048, 512
    act = active_params(cfg)
    toks = G * N * (PL + SL)
    t_update = 6 * act * toks / (ND * TPU_V5E.peak_flops)
    t_prefill = 2 * act * (G * N * PL) / (ND * TPU_V5E.peak_flops)
    cache_per_seq = (cfg.num_layers * (PL + SL / 2) * cfg.num_kv_heads
                     * cfg.head_dim * 2 * 2)          # k+v bf16, avg ctx
    step = (2 * act / ND + cache_per_seq * G * N / ND) / TPU_V5E.hbm_bw
    t_decode = SL * step
    ete = t_update + t_prefill + t_decode
    tput = toks / ND / ete
    print(f"\nEq.-5 roofline bound (512 chips, G=384 N=32 PL=1K SL=2K): "
          f"prefill {t_prefill:.1f}s + decode {t_decode:.1f}s + update "
          f"{t_update:.1f}s -> T <= {tput:.0f} tok/s/device.")
    print("paper measures 200-250 TPS for DeepSeek-R1-671B on 384 NPUs — "
          f"~{250 / tput * 100:.0f}% of this bound, a typical synchronous-RL "
          "efficiency once long-tail generation and stage bubbles are paid.")
    return True


if __name__ == "__main__":
    run()
