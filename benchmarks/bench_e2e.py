"""Paper Figure 7 — end-to-end RL throughput comparison.

Four system variants at CPU smoke scale (same relative mechanics as the
paper's 16-NPU runs):
  MSRL    — transfer dock + allgather-swap          (the full system)
  MSRLP   — neither technique (central buffer + naive reshard)
  MSRL-TD — transfer dock only
  MSRL-AS — allgather-swap only

Reports Eq. (5) throughput and the dataflow overheads that differ.
"""
from __future__ import annotations


from repro.configs import get_smoke_config
from repro.configs.base import RLConfig
from repro.core.trainer import GRPOTrainer
from repro.data.prompts import PromptDataset, pattern_task

VARIANTS = {
    "MSRL": dict(use_transfer_dock=True, use_allgather_swap=True),
    "MSRL-TD": dict(use_transfer_dock=True, use_allgather_swap=False),
    "MSRL-AS": dict(use_transfer_dock=False, use_allgather_swap=True),
    "MSRLP": dict(use_transfer_dock=False, use_allgather_swap=False),
}


def run(iterations: int = 3, global_batch: int = 4, arch: str = "yi-6b"):
    # NOTE: >=3 iterations — the swap path triggers ONE train_step recompile
    # when params first come back from host memory; steady state is measured.
    rows = []
    print("# Figure 7 — end-to-end variants (smoke scale)")
    print("variant,tokens_per_s_per_dev,dispatch_sim_s,reshard_peak_MB,"
          "released_MB")
    for name, flags in VARIANTS.items():
        cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
        rl = RLConfig(num_generations=2, max_prompt_len=16,
                      max_response_len=16, lr=1e-4, **flags)
        ds = PromptDataset(pattern_task(), max_prompt_len=16, seed=0)
        tr = GRPOTrainer(cfg, rl, ds, num_nodes=4, seed=0)
        stats = None
        for _ in range(iterations):
            stats = tr.iteration(global_batch)
        tput = tr.throughput(stats, global_batch)
        released = stats.reshard.get("d2h_bytes", 0)
        print(f"{name},{tput:.1f},"
              f"{stats.dispatch['simulated_dispatch_time_s']:.4f},"
              f"{stats.reshard['peak_device_bytes']/1e6:.1f},"
              f"{released/1e6:.1f}")
        rows.append((name, tput, stats.dispatch, stats.reshard))
    return rows


if __name__ == "__main__":
    run()
