"""Deterministic sampling: replay A/B + fused-truncation overhead.

Three claims, one artifact (``BENCH_sampling.json``):

  * REPLAY — with counter-based per-request streams, a sampled workload
    replayed from the same engine seed on a DIFFERENTLY-scheduled engine
    (half the slots, chunked prefill) reproduces every request's tokens
    and logp bitwise.  This is the contract that makes sampled RL
    rollouts debuggable: re-run any rollout from (params, prompts, seed)
    and get the same bits regardless of cluster load.  Asserted, not just
    reported.
  * ENGINE A/B — sampled ``ServingEngine.generate`` equals the sync
    ``RolloutEngine`` bitwise (tokens AND gen_logp) at block-aligned
    capacity.  Asserted.
  * OVERHEAD — tok/s of the continuous-batching drain under fused
    temperature/top-p/top-k sampling vs greedy argmax decoding: the
    truncation (stable sort + renormalized cumulative mass, inside the
    jitted drawer) is the measured cost of determinism-preserving
    sampling.

``PYTHONPATH=src python -m benchmarks.bench_sampling``
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.rollout import RolloutEngine
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve.engine import ServingEngine

B, PL, MN, BS, SLOTS = 8, 8, 24, 4, 4        # capacity 32: block-aligned
SAMP = dict(temperature=0.9, top_p=0.9, top_k=40)


def _prompts(seed: int = 0):
    return np.random.RandomState(seed).randint(0, 250, (B, PL)).astype(np.int32)


def _engine(tok, cfg, **kw):
    return ServingEngine(cfg, max_new=MN, eos_id=tok.eos_id,
                         pad_id=tok.pad_id, block_size=BS,
                         max_seq_len=PL + MN, **kw)


def _drain_rows(engine, params, prompts):
    for i, p in enumerate(prompts):
        engine.submit(p, seed=i)
    t0 = time.perf_counter()
    outs = engine.drain(params)
    dt = time.perf_counter() - t0
    rows = {o.rid: (tuple(int(t) for t in o.gen),
                    tuple(np.asarray(o.gen_logp, np.float32).tolist()))
            for o in outs}
    return rows, sum(len(o.gen) for o in outs), dt


def run(arch: str = "yi-6b"):
    cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
    tok = ByteTokenizer()
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    prompts = _prompts()

    # -- engine A/B: sampled serving == sampled sync, bitwise ----------------
    sync = RolloutEngine(cfg, max_new=MN, eos_id=tok.eos_id,
                         pad_id=tok.pad_id, **SAMP)
    srv = _engine(tok, cfg, max_slots=B, **SAMP)
    r1 = sync.generate(params, prompts, jax.random.PRNGKey(7))
    r2 = srv.generate(params, prompts, jax.random.PRNGKey(7))
    t = r2.gen_logp.shape[1]
    engine_match = (np.array_equal(r1.tokens, r2.tokens)
                    and np.array_equal(r1.gen_logp[:, :t], r2.gen_logp))
    print(f"sampled output match (serving == sync): {engine_match}")
    assert engine_match, "sampled serving diverged from RolloutEngine"

    # -- replay A/B: same seed, different schedule -> same bits --------------
    a = _engine(tok, cfg, max_slots=SLOTS, seed=11, **SAMP)
    rows_a, _, _ = _drain_rows(a, params, prompts)
    b = _engine(tok, cfg, max_slots=SLOTS // 2, prefill_chunk=5, seed=11,
                **SAMP)
    rows_b, _, _ = _drain_rows(b, params, prompts)
    replay_match = rows_a == rows_b
    print(f"replay match (slots={SLOTS} vs slots={SLOTS // 2}+chunked): "
          f"{replay_match}")
    assert replay_match, "replay-from-seed diverged across schedules"

    # -- overhead: fused sampled drain vs greedy drain -----------------------
    greedy = _engine(tok, cfg, max_slots=SLOTS, greedy=True)
    sampled = _engine(tok, cfg, max_slots=SLOTS, seed=11, **SAMP)
    _drain_rows(greedy, params, _prompts(1))         # warm (compile)
    _drain_rows(sampled, params, _prompts(1))
    _, g_tok, g_dt = _drain_rows(greedy, params, prompts)
    _, s_tok, s_dt = _drain_rows(sampled, params, prompts)
    g_rate, s_rate = g_tok / g_dt, s_tok / s_dt
    overhead = g_rate / s_rate - 1.0
    print("mode,tok,wall_s,tok_per_s")
    print(f"greedy,{g_tok},{g_dt:.2f},{g_rate:.1f}")
    print(f"sampled,{s_tok},{s_dt:.2f},{s_rate:.1f}")
    print(f"fused top-p/top-k sampling overhead: {overhead * 100:.1f}%")

    st = sampled.stats()
    for e in (srv, a, b, greedy, sampled):
        e.close()
    return {
        "engine_match": bool(engine_match),
        "replay_match": bool(replay_match),
        "greedy_tok_s": g_rate,
        "sampled_tok_s": s_rate,
        "sampling_overhead_frac": overhead,
        "sampled_requests": st["sampled_requests"],
        "sampled_tokens": st["sampled_tokens"],
    }


if __name__ == "__main__":
    run()
