"""GRPO trainer — the MindSpeed-RL iteration as a declared dataflow graph:

  generation stage  -> inference stage -> update stage
        ^                                     |
        +---- resharding flow (allgather-swap) ----+

The algorithm is DECLARED in ``build_grpo_graph`` as stage nodes over dock
fields; the shared ``GraphExecutor`` (core/graph.py) schedules any node
whose inputs are ready per the transfer-dock metadata, handles the
update<->generation weight-layout transitions that the graph's layout
edges demand, and fuses independent ready stages (ref-inference ∥ reward ∥
actor-inference) by dispatching them concurrently.  Runs for real on CPU at
smoke scale (the end-to-end examples) and is the template the launch layer
lowers at production scale.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core import grpo
from repro.core.graph import GraphExecutor, RLGraph, StageNode
from repro.core.resharding import Resharder
from repro.core.transfer_dock import (CentralReplayBuffer, DispatchLedger,
                                      TransferDock)
from repro.core.workers import ActorWorker, ReferenceWorker, RewardWorker
from repro.resilience import call_with_retry
from repro.data.prompts import PromptDataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.obs import Tracer, get_tracer
from repro.optim import adamw_init
from repro.sharding import param_specs


@dataclass
class IterationStats:
    reward_mean: float
    reward_std: float
    loss: float
    kl: float
    gen_time: float
    infer_time: float
    update_time: float
    reshard: dict = field(default_factory=dict)
    dispatch: dict = field(default_factory=dict)
    trace: list = field(default_factory=list)   # executor (node, idxs) log


# ---------------------------------------------------------------------------
# graph declaration — the paper's Fig. 1 nodes/edges for GRPO/DAPO
# ---------------------------------------------------------------------------

def build_grpo_graph(actor_node: int = 0, ref_node: int = 1,
                     reward_node: int = 2) -> RLGraph:
    """GRPO as an RLGraph: generation fans out to three independent
    consumers (actor/ref inference + reward — the fusion set), rewards
    gather into group advantages, and everything joins at the update."""
    T = GRPOTrainer
    return RLGraph("grpo", [
        StageNode("actor_generation", actor_node,
                  inputs=("prompt",),
                  outputs=("tokens", "response_mask"),
                  fn=T._stage_generate, layout="generation", timing="gen"),
        StageNode("actor_inference", actor_node,
                  inputs=("tokens",), outputs=("old_logp",),
                  fn=T._stage_old_logp, layout="update"),
        StageNode("ref_inference", ref_node,
                  inputs=("tokens",), outputs=("ref_logp",),
                  fn=T._stage_ref_logp, stream=True),
        StageNode("reward", reward_node,
                  inputs=("tokens",), outputs=("rewards",),
                  fn=T._stage_reward, stream=True),
        StageNode("advantages", reward_node,
                  inputs=("rewards",), outputs=("advantages",),
                  fn=T._stage_advantages),
        StageNode("actor_update", actor_node,
                  inputs=("tokens", "response_mask", "old_logp", "ref_logp",
                          "advantages"),
                  outputs=(),
                  fn=T._stage_update, layout="update", timing="update"),
    ])


class GRPOTrainer:
    """Owns model/optimizer state and the workers; the iteration itself is
    ``self.graph`` executed by the shared ``GraphExecutor``."""

    clear_dock_each_iteration = True
    # subclasses may pin the actor's generation engine (None => honor
    # rl.rollout_engine); partial rollout pins "serving" — budgeted resume
    # is an engine capability, not a trainer loop
    actor_engine_kind: str | None = None

    def __init__(self, cfg: ModelConfig, rl: RLConfig, dataset: PromptDataset,
                 *, num_nodes: int = 4, microbatch: int = 0, seed: int = 0,
                 mesh=None, tracer=None, faults=None):
        assert cfg.vocab_size >= ByteTokenizer.vocab_size
        if rl.partial_rollout and self.clear_dock_each_iteration:
            # the flag is honored by the PartialRolloutTrainer graph (which
            # keeps dock indices across iterations); silently running plain
            # GRPO/PPO against it would be a no-op the user cannot see
            raise ValueError(
                "rl.partial_rollout=True needs PartialRolloutTrainer "
                "(core/partial.py), not " + type(self).__name__)
        self.cfg = cfg
        self.rl = rl
        self.dataset = dataset
        self.key = jax.random.PRNGKey(seed)
        self.tok = dataset.tok
        self.microbatch = microbatch
        # one tracer serves every instrumented layer (executor spans, dock
        # counter events, serving-engine steps): injected > rl.trace_path
        # (fresh enabled tracer) > the disabled process default
        self.tracer = tracer if tracer is not None else (
            Tracer(enabled=True) if rl.trace_path else get_tracer())
        self.faults = faults     # FaultPlan | None — chaos hooks everywhere
        self._iters_run = 0

        # --- model / optimizer state -----------------------------------
        model = build_model(cfg)
        self.key, k = jax.random.split(self.key)
        self.params = model.init(cfg, k)
        # genuine copy: train_step donates self.params' buffers, so the
        # frozen reference policy must own distinct ones
        self.ref_params = jax.tree.map(jnp.copy, self.params)
        self.opt_state = adamw_init(self.params)
        self.train_step = jax.jit(grpo.make_train_step(cfg, rl),
                                  donate_argnums=(0, 1))
        self.gen_params = None   # generation-layout weights (executor-owned)

        # --- distribution -----------------------------------------------
        self.mesh = mesh or make_local_mesh()
        tspecs = param_specs(cfg, self.params, self.mesh, stage="train")
        gspecs = param_specs(cfg, self.params, self.mesh, stage="gen",
                             gen_mode="tp")
        self.resharder = Resharder(self.mesh, tspecs, gspecs,
                                   use_swap=rl.use_allgather_swap)

        # --- workers + graph + dock --------------------------------------
        self.actor = ActorWorker(cfg, rl, eos_id=self.tok.eos_id,
                                 pad_id=self.tok.pad_id, node=0,
                                 engine=self.actor_engine_kind,
                                 tracer=self.tracer, faults=faults)
        self.ref = ReferenceWorker(cfg, self.ref_params, node=1 % num_nodes)
        self.reward = RewardWorker(dataset, node=2 % num_nodes)
        self.graph = self._build_graph()
        ledger = DispatchLedger(internode_bw=rl.internode_bw,
                                tracer=self.tracer)
        if rl.use_transfer_dock:
            self.dock = TransferDock(min(rl.num_warehouses, num_nodes),
                                     self.graph.states(), ledger,
                                     faults=faults)
        else:
            self.dock = CentralReplayBuffer(self.graph.states(), ledger,
                                            faults=faults)
        self.executor = GraphExecutor(self.dock, rl, tracer=self.tracer,
                                      faults=faults)
        self.last_run = None

    def _build_graph(self) -> RLGraph:
        return build_grpo_graph(self.actor.node, self.ref.node,
                                self.reward.node)

    # ------------------------------------------------------------------
    # per-iteration prompt enqueue (the graph's external field)
    # ------------------------------------------------------------------
    def _enqueue(self, global_batch: int) -> int | None:
        """Put this iteration's prompts into the dock; returns the expected
        per-stage sample count (None => greedy scheduling)."""
        G, N = global_batch, self.rl.num_generations
        total = G * N
        prompts, plens, metas = self.dataset.sample(G)
        self._plen = prompts.shape[1]
        prompts_rep = np.repeat(prompts, N, axis=0)
        self._metas = {i: metas[i // N] for i in range(total)}
        # the dock.put fault site fires at entry, before any row lands, so a
        # retried put is exactly once-effective (same rows, same idxs)
        call_with_retry(
            lambda: self.dock.put("prompt", list(range(total)), prompts_rep,
                                  src_node=self.actor.node),
            self.executor.retry)
        return total

    # ------------------------------------------------------------------
    # stage callables (the graph nodes' fns)
    # ------------------------------------------------------------------
    def _stage_generate(self, io):
        self.key, k = jax.random.split(self.key)
        pbatch = io.ins["prompt"]
        if self.actor.engine_kind == "serving":
            # continuous batching: each finished sample flows into the dock
            # the MOMENT its sequence completes, not at the batch barrier —
            # the executor sees per-sample readiness and starts stream
            # stages (ref_inference, reward) before generation drains.
            idxs = io.idxs

            def _stream(i, tokens_row, mask_row, length):
                io.put("tokens", [idxs[i]], tokens_row[None])
                io.put("response_mask", [idxs[i]], mask_row[None])

            self.actor.generate(self.gen_params, pbatch, k,
                                on_finish=_stream)
            return None
        roll = self.actor.generate(self.gen_params, pbatch, k)
        return {"tokens": roll.tokens, "response_mask": roll.response_mask}

    def _stage_old_logp(self, io):
        return {"old_logp": self.actor.old_logprobs(self.params,
                                                    io.ins["tokens"])}

    def _stage_ref_logp(self, io):
        return {"ref_logp": self.ref.logprobs(io.ins["tokens"])}

    def _stage_reward(self, io):
        rw = self.reward.score([self._metas[i] for i in io.idxs],
                               io.ins["tokens"], self._plen)
        for idx, r in zip(io.idxs, rw):
            self._it["reward_by_idx"][idx] = float(r)
        return {"rewards": np.asarray(rw)[:, None]}

    def _stage_advantages(self, io):
        N = self.rl.num_generations
        rw = io.ins["rewards"][:, 0]
        self._it["rewards_arr"] = rw
        adv = np.asarray(
            grpo.group_advantages(jnp.asarray(rw.reshape(-1, N)))
        ).reshape(-1)
        return {"advantages": adv[:, None]}

    def _stage_update(self, io):
        ins = io.ins
        n = len(io.idxs)
        mb = self.microbatch or n
        for lo in range(0, n, mb):
            sl = slice(lo, lo + mb)
            batch = {
                "tokens": jnp.asarray(ins["tokens"][sl]),
                "response_mask": jnp.asarray(ins["response_mask"][sl]),
                "old_logp": jnp.asarray(ins["old_logp"][sl]),
                "ref_logp": jnp.asarray(ins["ref_logp"][sl]),
                "advantages": jnp.asarray(ins["advantages"][sl])[:, 0],
            }
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            self._it["losses"].append(float(metrics["loss"]))
            self._it["kls"].append(float(metrics["kl"]))
        return None

    # ------------------------------------------------------------------
    def iteration(self, global_batch: int) -> IterationStats:
        """One RL iteration: enqueue prompts, run the graph to quiescence."""
        if self.clear_dock_each_iteration:
            self.dock.clear()
        expected = self._enqueue(global_batch)
        self._it = {"losses": [], "kls": [], "reward_by_idx": {}}
        with self.tracer.span("iteration", cat="train",
                              args={"iteration": self._iters_run,
                                    "global_batch": global_batch}):
            run = self.executor.run(self.graph, self, expected=expected)
        self._iters_run += 1
        self.last_run = run
        return self._stats(run)

    def export_trace(self, path: str | None = None) -> str:
        """Dump the tracer's Chrome-trace JSON (openable in Perfetto)."""
        path = path or self.rl.trace_path
        if path is None:
            raise ValueError("no trace path: pass one or set rl.trace_path")
        return self.tracer.export(path)

    def _stats(self, run) -> IterationStats:
        it = self._it
        rw = it.get("rewards_arr")
        if rw is None and it["reward_by_idx"]:
            rw = np.asarray([it["reward_by_idx"][i]
                             for i in sorted(it["reward_by_idx"])])
        losses, kls = it["losses"], it["kls"]
        return IterationStats(
            reward_mean=float(np.mean(rw)) if rw is not None and len(rw)
            else 0.0,
            reward_std=float(np.std(rw)) if rw is not None and len(rw)
            else 0.0,
            loss=float(np.mean(losses)) if losses else 0.0,
            kl=it.get("kl_stat",
                      float(np.mean(kls)) if kls else 0.0),
            gen_time=run.stage_times["gen"],
            infer_time=run.stage_times["infer"],
            update_time=run.stage_times["update"],
            reshard=run.reshard.snapshot(),
            dispatch=self.dock.ledger.snapshot(),
            trace=list(run.trace),
        )

    def throughput(self, stats: IterationStats, global_batch: int,
                   num_devices: int = 1) -> float:
        """Paper Eq. (5): T = G*N*(PL+SL) / ND / ETE."""
        ete = stats.gen_time + stats.infer_time + stats.update_time
        toks = (global_batch * self.rl.num_generations
                * (self.rl.max_prompt_len + self.rl.max_response_len))
        return toks / max(num_devices, 1) / max(ete, 1e-9)
