"""GRPO trainer — the full MindSpeed-RL iteration:

  generation stage  -> inference stage -> update stage
        ^                                     |
        +---- resharding flow (allgather-swap) ----+

with the sample flow routed through the distributed transfer dock.  Runs for
real on CPU at smoke scale (the end-to-end examples) and is the template the
launch layer lowers at production scale.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core import grpo
from repro.core.resharding import Resharder
from repro.core.transfer_dock import (CentralReplayBuffer, DispatchLedger,
                                      TransferDock)
from repro.core.workers import ActorWorker, ReferenceWorker, RewardWorker
from repro.data.prompts import PromptDataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.optim import adamw_init
from repro.sharding import param_specs


@dataclass
class IterationStats:
    reward_mean: float
    reward_std: float
    loss: float
    kl: float
    gen_time: float
    infer_time: float
    update_time: float
    reshard: dict = field(default_factory=dict)
    dispatch: dict = field(default_factory=dict)


class GRPOTrainer:
    def __init__(self, cfg: ModelConfig, rl: RLConfig, dataset: PromptDataset,
                 *, num_nodes: int = 4, microbatch: int = 0, seed: int = 0,
                 mesh=None):
        assert cfg.vocab_size >= ByteTokenizer.vocab_size
        self.cfg = cfg
        self.rl = rl
        self.dataset = dataset
        self.key = jax.random.PRNGKey(seed)
        self.tok = dataset.tok
        self.microbatch = microbatch

        # --- model / optimizer state -----------------------------------
        model = build_model(cfg)
        self.key, k = jax.random.split(self.key)
        self.params = model.init(cfg, k)
        # genuine copy: train_step donates self.params' buffers, so the
        # frozen reference policy must own distinct ones
        self.ref_params = jax.tree.map(jnp.copy, self.params)
        self.opt_state = adamw_init(self.params)
        self.train_step = jax.jit(grpo.make_train_step(cfg, rl),
                                  donate_argnums=(0, 1))

        # --- distribution -----------------------------------------------
        self.mesh = mesh or make_local_mesh()
        tspecs = param_specs(cfg, self.params, self.mesh, stage="train")
        gspecs = param_specs(cfg, self.params, self.mesh, stage="gen",
                             gen_mode="tp")
        self.resharder = Resharder(self.mesh, tspecs, gspecs,
                                   use_swap=rl.use_allgather_swap)

        # --- workers + dock ----------------------------------------------
        self.actor = ActorWorker(cfg, rl, eos_id=self.tok.eos_id,
                                 pad_id=self.tok.pad_id, node=0)
        self.ref = ReferenceWorker(cfg, self.ref_params, node=1 % num_nodes)
        self.reward = RewardWorker(dataset, node=2 % num_nodes)
        states = {
            "actor_generation": 0,
            "actor_inference": 0,
            "ref_inference": self.ref.node,
            "reward": self.reward.node,
            "actor_update": 0,
        }
        ledger = DispatchLedger(internode_bw=rl.internode_bw)
        if rl.use_transfer_dock:
            self.dock = TransferDock(min(rl.num_warehouses, num_nodes),
                                     states, ledger)
        else:
            self.dock = CentralReplayBuffer(states, ledger)

    # ------------------------------------------------------------------
    def iteration(self, global_batch: int) -> IterationStats:
        """One RL iteration over G prompts × N generations."""
        cfg, rl = self.cfg, self.rl
        G, N = global_batch, rl.num_generations
        total = G * N
        self.dock.clear()

        prompts, plens, metas = self.dataset.sample(G)
        pl = prompts.shape[1]
        prompts_rep = np.repeat(prompts, N, axis=0)
        metas_rep = [metas[i // N] for i in range(total)]
        idxs = list(range(total))
        self.dock.put("prompt", idxs, prompts_rep, src_node=0)

        # ---- resharding flow: update layout -> generation layout -------
        gen_params, stash, reshard_led = self.resharder.to_generation(
            self.params)
        del self.params  # paper semantics: update buffers leave the device

        # ---- generation stage ------------------------------------------
        t0 = time.perf_counter()
        ready = self.dock.request_metadata("actor_generation", ["prompt"])
        pbatch = self.dock.get("actor_generation", "prompt", ready,
                               dst_node=self.actor.node)
        self.key, k = jax.random.split(self.key)
        if self.actor.engine_kind == "serving":
            # continuous batching: each finished sample flows into the dock
            # the MOMENT its sequence completes, not at the batch barrier —
            # downstream stages see readiness metadata per sample.
            node = self.actor.node

            def _stream(i, tokens_row, mask_row, length):
                self.dock.put("tokens", [ready[i]], tokens_row[None],
                              src_node=node)
                self.dock.put("response_mask", [ready[i]], mask_row[None],
                              src_node=node)

            rollout = self.actor.generate(gen_params, pbatch, k,
                                          on_finish=_stream)
        else:
            rollout = self.actor.generate(gen_params, pbatch, k)
            self.dock.put("tokens", ready, rollout.tokens,
                          src_node=self.actor.node)
            self.dock.put("response_mask", ready, rollout.response_mask,
                          src_node=self.actor.node)
        self.dock.mark_consumed("actor_generation", ready)
        gen_time = time.perf_counter() - t0
        del gen_params

        # ---- H2D swap back, overlapped with the inference stage --------
        self.params, reshard_led = self.resharder.to_update(
            stash, reshard_led)

        # ---- inference stage --------------------------------------------
        t0 = time.perf_counter()
        ready = self.dock.request_metadata("actor_inference", ["tokens"])
        toks = self.dock.get("actor_inference", "tokens", ready, dst_node=0)
        old_logp = self.actor.old_logprobs(self.params, toks)
        self.dock.put("old_logp", ready, old_logp, src_node=0)
        self.dock.mark_consumed("actor_inference", ready)

        # ref-inference and reward are independent consumers of the same
        # samples; with stage fusion (paper Table 2) they run CONCURRENTLY —
        # ref's jitted forward releases the GIL while the rule reward scores
        # on the host.
        ready_ref = self.dock.request_metadata("ref_inference", ["tokens"])
        toks_ref = self.dock.get("ref_inference", "tokens", ready_ref,
                                 dst_node=self.ref.node)
        ready_rw = self.dock.request_metadata("reward", ["tokens"])
        toks_rw = self.dock.get("reward", "tokens", ready_rw,
                                dst_node=self.reward.node)
        if self.rl.stage_fusion:
            from concurrent.futures import ThreadPoolExecutor

            with ThreadPoolExecutor(max_workers=2) as ex:
                f_ref = ex.submit(self.ref.logprobs, toks_ref)
                f_rw = ex.submit(self.reward.score,
                                 [metas_rep[i] for i in ready_rw],
                                 toks_rw, pl)
                ref_logp, rewards = f_ref.result(), f_rw.result()
        else:
            ref_logp = self.ref.logprobs(toks_ref)
            rewards = self.reward.score([metas_rep[i] for i in ready_rw],
                                        toks_rw, pl)
        self.dock.put("ref_logp", ready_ref, ref_logp, src_node=self.ref.node)
        self.dock.mark_consumed("ref_inference", ready_ref)
        ready = ready_rw
        adv = np.asarray(
            grpo.group_advantages(jnp.asarray(rewards.reshape(G, N)))
        ).reshape(-1)
        self.dock.put("advantages", ready, adv[:, None],
                      src_node=self.reward.node)
        self.dock.mark_consumed("reward", ready)
        infer_time = time.perf_counter() - t0

        # ---- update stage ------------------------------------------------
        t0 = time.perf_counter()
        ready = self.dock.request_metadata(
            "actor_update",
            ["tokens", "response_mask", "old_logp", "ref_logp", "advantages"])
        mb = self.microbatch or len(ready)
        losses, kls = [], []
        for lo in range(0, len(ready), mb):
            sel = ready[lo:lo + mb]
            batch = {
                "tokens": jnp.asarray(self.dock.get(
                    "actor_update", "tokens", sel, 0)),
                "response_mask": jnp.asarray(self.dock.get(
                    "actor_update", "response_mask", sel, 0)),
                "old_logp": jnp.asarray(self.dock.get(
                    "actor_update", "old_logp", sel, 0)),
                "ref_logp": jnp.asarray(self.dock.get(
                    "actor_update", "ref_logp", sel, 0)),
                "advantages": jnp.asarray(self.dock.get(
                    "actor_update", "advantages", sel, 0))[:, 0],
            }
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            losses.append(float(metrics["loss"]))
            kls.append(float(metrics["kl"]))
        self.dock.mark_consumed("actor_update", ready)
        update_time = time.perf_counter() - t0

        return IterationStats(
            reward_mean=float(np.mean(rewards)),
            reward_std=float(np.std(rewards)),
            loss=float(np.mean(losses)),
            kl=float(np.mean(kls)),
            gen_time=gen_time,
            infer_time=infer_time,
            update_time=update_time,
            reshard=reshard_led.snapshot(),
            dispatch=self.dock.ledger.snapshot(),
        )

    def throughput(self, stats: IterationStats, global_batch: int,
                   num_devices: int = 1) -> float:
        """Paper Eq. (5): T = G*N*(PL+SL) / ND / ETE."""
        ete = stats.gen_time + stats.infer_time + stats.update_time
        toks = (global_batch * self.rl.num_generations
                * (self.rl.max_prompt_len + self.rl.max_response_len))
        return toks / max(num_devices, 1) / max(ete, 1e-9)
