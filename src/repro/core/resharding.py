"""Resharding flow: update-stage layout <-> generation-stage layout.

Implements the paper's two strategies:

  * ``naive_reshard``   — Figure 3 baseline: materialize the generation-layout
    weights while the update-layout weights are still resident, leaving the
    update buffers on device for the whole generation stage (redundant
    memory R of Eq. 3 == the entire per-device update partition).

  * ``allgather_swap``  — Figure 5: (1) temp-buffer allgather of the update
    weights, (2) slice-select the generation shard, (3) swap the update
    weights D2H into ``pinned_host`` memory (fully releasing device memory
    for the KV cache), (4) free the temp buffer.  Before the next update the
    weights are swapped H2D (overlappable with the inference stage).

On TPU the D2H/H2D path is the native ``memory_kind="pinned_host"``; the CPU
container exposes the same memory kinds, so the identical code runs here.
Every step is recorded in a ``ReshardLedger`` (per-device bytes + modeled
durations with the paper's 50 GB/s H2D bandwidth), which benchmarks use to
reproduce Figure 10.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# size accounting
# ---------------------------------------------------------------------------

def _axis_size(mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def per_device_bytes(leaf, spec: P, mesh) -> int:
    """Bytes of one device's shard (ceil for uneven sharding)."""
    shape = list(leaf.shape)
    for i, ax in enumerate(spec):
        n = _axis_size(mesh, ax)
        shape[i] = -(-shape[i] // n)
    n = int(np.prod(shape)) if shape else 1
    return n * jnp.dtype(leaf.dtype).itemsize


def tree_device_bytes(tree, specs, mesh) -> int:
    total = 0
    leaves = jax.tree.leaves(tree)
    specl = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(leaves, specl):
        total += per_device_bytes(leaf, spec, mesh)
    return total


def tree_global_bytes(tree) -> int:
    return sum(l.size * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# ledger
# ---------------------------------------------------------------------------

@dataclass
class ReshardLedger:
    """Per-device memory timeline + modeled durations of one reshard."""
    events: list = field(default_factory=list)   # (label, device_bytes_delta)
    d2h_bytes: int = 0
    h2d_bytes: int = 0
    gathered_bytes: int = 0
    h2d_bw: float = 50e9
    wall_s: float = 0.0

    def log(self, label: str, delta: int):
        self.events.append((label, int(delta)))

    def timeline(self) -> list:
        """(label, cumulative per-device bytes) after each event."""
        out, cur = [], 0
        for label, d in self.events:
            cur += d
            out.append((label, cur))
        return out

    @property
    def peak_bytes(self) -> int:
        return max((b for _, b in self.timeline()), default=0)

    @property
    def swap_time_s(self) -> float:
        return (self.d2h_bytes + self.h2d_bytes) / self.h2d_bw

    def snapshot(self) -> dict:
        return {
            "timeline": self.timeline(),
            "peak_device_bytes": self.peak_bytes,
            "d2h_bytes": self.d2h_bytes,
            "h2d_bytes": self.h2d_bytes,
            "modeled_swap_time_s": self.swap_time_s,
            "wall_s": self.wall_s,
        }


# ---------------------------------------------------------------------------
# resharder
# ---------------------------------------------------------------------------

def _host_sharding(sh: NamedSharding) -> NamedSharding:
    return NamedSharding(sh.mesh, sh.spec, memory_kind="pinned_host")


class Resharder:
    """Moves the actor weights between the two stage layouts."""

    def __init__(self, mesh, train_specs, gen_specs, *,
                 use_swap: bool = True, paper_two_step: bool = False):
        self.mesh = mesh
        self.train_specs = train_specs
        self.gen_specs = gen_specs
        self.train_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), train_specs,
            is_leaf=lambda x: isinstance(x, P))
        self.gen_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), gen_specs,
            is_leaf=lambda x: isinstance(x, P))
        self.use_swap = use_swap
        self.paper_two_step = paper_two_step
        self._supports_host = self._detect_host_memory()

    def _detect_host_memory(self) -> bool:
        try:
            kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
            return "pinned_host" in kinds
        except Exception:
            return False

    # -- generation direction -------------------------------------------------
    def to_generation(self, params):
        """Returns (gen_params, stash, ledger).  ``stash`` holds the update
        weights off the device (host memory kind, or numpy fallback) and is
        consumed by ``to_update``."""
        led = ReshardLedger()
        t0 = time.perf_counter()
        mesh = self.mesh
        upd_dev = tree_device_bytes(params, self.train_specs, mesh)
        led.log("update weights resident", upd_dev)

        if self.paper_two_step:
            # Figure 5 steps 1-2 literally: full allgather temp, then select.
            repl = jax.tree.map(
                lambda l: jax.device_put(l, NamedSharding(
                    mesh, P(*([None] * l.ndim)))), params)
            temp = tree_device_bytes(repl, jax.tree.map(
                lambda l: P(*([None] * l.ndim)), params,
                is_leaf=lambda x: hasattr(x, "ndim")), mesh)
            led.log("temp allgather buffer", temp)
            led.gathered_bytes = temp
            gen = jax.device_put(repl, self.gen_shardings)
            led.log("generation slices selected",
                    tree_device_bytes(gen, self.gen_specs, mesh))
            del repl
            led.log("temp buffer freed", -temp)
        else:
            # fused gather+select (XLA emits the minimal collective)
            gen = jax.device_put(params, self.gen_shardings)
            gb = tree_device_bytes(gen, self.gen_specs, mesh)
            led.gathered_bytes = gb
            led.log("generation layout materialized", gb)

        if self.use_swap:
            if self._supports_host:
                host = jax.tree.map(
                    lambda l, sh: jax.device_put(l, _host_sharding(sh)),
                    params, self.train_shardings)
            else:
                host = jax.tree.map(lambda l: np.asarray(jax.device_get(l)),
                                    params)
            led.d2h_bytes = tree_device_bytes(params, self.train_specs, mesh)
            jax.block_until_ready(jax.tree.leaves(gen))
            led.log("update weights swapped D2H", -upd_dev)
            stash = ("host", host)
        else:
            # naive: update weights stay resident for the whole generation
            stash = ("device", params)
        led.wall_s = time.perf_counter() - t0
        return gen, stash, led

    # -- update direction ------------------------------------------------------
    def to_update(self, stash, ledger: ReshardLedger | None = None):
        """H2D swap back (overlap with inference by calling early — JAX
        dispatch is async)."""
        kind, host = stash
        led = ledger or ReshardLedger()
        t0 = time.perf_counter()
        if kind == "device":
            return host, led
        params = jax.tree.map(
            lambda l, sh: jax.device_put(l, sh), host, self.train_shardings)
        led.h2d_bytes = tree_device_bytes(params, self.train_specs, self.mesh)
        led.log("update weights swapped H2D",
                tree_device_bytes(params, self.train_specs, self.mesh))
        led.wall_s += time.perf_counter() - t0
        return params, led

    # -- analytics -------------------------------------------------------------
    def redundancy_bytes(self, params) -> int:
        """Eq. (3): device bytes the NAIVE flow wastes during generation —
        the whole per-device update partition that allgather-swap releases."""
        return tree_device_bytes(params, self.train_specs, self.mesh)


def naive_reshard(mesh, params, gen_specs):
    """Baseline: reshard keeping update weights resident."""
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), gen_specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(params, shardings)
