"""Distributed Transfer Dock (TD) — the paper's sample-flow contribution.

The conventional centralized replay buffer is split into:

  * ``TDWarehouse``  — S shards of the sample store, sharded along the global
    batch dimension (sample index % S); one warehouse per node.
  * ``TDController`` — one per WORKER STATE (actor-generation,
    actor-inference, ref-inference, reward, actor-update, ...), holding only
    metadata: which sample indices have which fields ready, and which
    warehouse owns them.  Controllers are co-located with their worker, so
    metadata requests are intranode.

Every byte movement is recorded in a ``DispatchLedger`` with the paper's
bandwidth model (300 MB/s inter-server by default), so benchmarks can
reproduce Table 1 / Figure 9 while the SAME code path does the real (numpy)
data movement for the CPU-scale end-to-end examples.

``CentralReplayBuffer`` is the baseline: one warehouse pinned to node 0 and a
single controller, so every worker request crosses the network (unless the
worker sits on node 0) — the K1.5-style design the paper improves on.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

META_SCALAR_BYTES = 4      # paper: metadata are int32 scalars
META_PER_SAMPLE = 3        # sample idx, warehouse idx, ready bitmap


@dataclass
class DispatchLedger:
    """Byte/message accounting for the sample flow, with an optional tracer:
    when one is attached and enabled, every ``record``/``record_meta``
    becomes a cumulative counter sample (``dock.bytes`` tagged intranode vs
    internode, ``dock.metadata``) on the same timeline as the stage spans
    that caused the traffic — the dispatch-cost half of the paper's
    accounting claim, visible in Perfetto next to the compute it serves."""

    internode_bytes: int = 0
    intranode_bytes: int = 0
    metadata_bytes: int = 0
    metadata_msgs: int = 0
    requests: int = 0
    internode_bw: float = 300e6
    metadata_latency: float = 1e-4     # per metadata round-trip (Ray-like RPC)
    per_node_bytes: dict = field(default_factory=dict)  # warehouse-node load
    tracer: object = None              # repro.obs.Tracer | None

    def record(self, nbytes: int, cross: bool, node: int = 0):
        if cross:
            self.internode_bytes += nbytes
            self.per_node_bytes[node] = (
                self.per_node_bytes.get(node, 0) + nbytes)
        else:
            self.intranode_bytes += nbytes
        self.requests += 1
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.counter("dock.bytes", {"internode": self.internode_bytes,
                                      "intranode": self.intranode_bytes},
                       cat="dock")

    def record_meta(self, nbytes: int, msgs: int = 1):
        """Metadata-plane accounting.  ``nbytes`` always accumulates;
        ``msgs`` counts only LATENCY-BEARING messages — round-trips that
        cross a process/RPC boundary and therefore pay
        ``metadata_latency`` in ``simulated_dispatch_time``.  The two
        in-repo semantics (pinned by tests/test_obs.py):

          * PUT — the warehouse broadcasts readiness to all controllers
            (paper step 3): one message per controller, ``msgs=nctl``.
          * GET/metadata request — ``TransferDock`` co-locates each
            controller with its worker, so the request is intranode and
            FREE latency-wise (``msgs=0``, bytes still counted); the
            ``CentralReplayBuffer`` baseline's single controller sits on
            node 0, so every request is a real RPC (``msgs=1``).

        That asymmetry IS the paper's metadata-locality argument — do not
        "fix" it by counting intranode requests as messages."""
        self.metadata_bytes += nbytes
        self.metadata_msgs += msgs
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.counter("dock.metadata", {"bytes": self.metadata_bytes,
                                         "msgs": self.metadata_msgs},
                       cat="dock")

    @property
    def simulated_dispatch_time(self) -> float:
        """Seconds the sample flow takes at the modeled bandwidth.  Warehouses
        serve in PARALLEL, so the wall time is the max per-node load — this is
        what makes S warehouses ~S× faster than the centralized buffer."""
        busiest = max(self.per_node_bytes.values(), default=0)
        return (busiest / self.internode_bw
                + self.metadata_msgs * self.metadata_latency)

    def snapshot(self) -> dict:
        return {
            "internode_bytes": self.internode_bytes,
            "intranode_bytes": self.intranode_bytes,
            "metadata_bytes": self.metadata_bytes,
            "metadata_msgs": self.metadata_msgs,
            "requests": self.requests,
            "per_node_bytes": dict(self.per_node_bytes),
            "simulated_dispatch_time_s": self.simulated_dispatch_time,
        }


class TDWarehouse:
    def __init__(self, node: int):
        self.node = node
        self.store: dict[str, dict[int, np.ndarray]] = {}

    def put(self, fld: str, idx: int, row: np.ndarray):
        self.store.setdefault(fld, {})[idx] = row

    def get(self, fld: str, idx: int) -> np.ndarray:
        return self.store[fld][idx]

    def clear(self):
        self.store.clear()


class TDController:
    """Metadata for ONE worker state: which samples are ready/consumed."""

    def __init__(self, state: str, node: int):
        self.state = state
        self.node = node
        self.ready: dict[int, set] = {}
        self.consumed: set = set()

    def on_meta(self, idx: int, fld: str):
        self.ready.setdefault(idx, set()).add(fld)

    def available(self, fields, limit: int | None = None) -> list[int]:
        need = set(fields)
        out = [i for i, f in sorted(self.ready.items())
               if need <= f and i not in self.consumed]
        return out if limit is None else out[:limit]


class TransferDock:
    """S warehouses + one controller per worker state."""

    name = "transfer_dock"

    def __init__(self, num_warehouses: int, states: dict[str, int],
                 ledger: DispatchLedger | None = None, faults=None):
        """states: worker-state name -> node id it runs on."""
        self.S = num_warehouses
        self.warehouses = [TDWarehouse(node=w) for w in range(num_warehouses)]
        self.controllers = {s: TDController(s, node) for s, node in
                            states.items()}
        self.ledger = ledger or DispatchLedger()
        self.faults = faults              # FaultPlan | None (chaos hook)
        # per-field row prototype (shape, dtype), remembered at first put so
        # empty gets stay well-shaped even after rows are consumed/cleared —
        # a field's row geometry is fixed by the algorithm config, not by
        # which samples currently sit in the warehouses
        self._proto: dict[str, tuple] = {}

    # -- routing ------------------------------------------------------------
    def _wh(self, idx: int) -> TDWarehouse:
        return self.warehouses[idx % self.S]

    # -- data plane ---------------------------------------------------------
    def put(self, fld: str, idxs, rows, src_node: int):
        """rows: array (n, ...) or list of per-sample arrays."""
        # fault site at ENTRY, before any row or metadata lands: a failed
        # put leaves the dock untouched, so the caller's retry re-runs the
        # identical put exactly once-effective (docs/resilience.md)
        if self.faults is not None:
            self.faults.check("dock.put")
        for j, idx in enumerate(idxs):
            row = np.asarray(rows[j])
            if fld not in self._proto:
                self._proto[fld] = (row.shape, row.dtype)
            wh = self._wh(idx)
            self.ledger.record(row.nbytes, cross=wh.node != src_node,
                               node=wh.node)
            wh.put(fld, int(idx), row)
        # warehouse broadcasts metadata to ALL controllers (paper step 3)
        nctl = len(self.controllers)
        self.ledger.record_meta(
            len(idxs) * META_PER_SAMPLE * META_SCALAR_BYTES * nctl, msgs=nctl)
        for ctl in self.controllers.values():
            for idx in idxs:
                ctl.on_meta(int(idx), fld)

    def get(self, state: str, fld: str, idxs, dst_node: int) -> np.ndarray:
        if not len(idxs):
            # well-shaped empty batch so streaming/graph consumers can poll —
            # sized from the field's prototype (first row ever put), never
            # invented: a made-up (0, 0) float32 would lie about width/dtype
            # to whatever concatenates downstream
            proto = self._proto.get(fld)
            if proto is None:
                raise KeyError(
                    f"transfer dock: empty get of field {fld!r} (worker "
                    f"state {state!r}) before any put of that field — there "
                    f"is no prototype row to size the empty batch; known "
                    f"fields: {sorted(self._proto)}")
            return np.empty((0,) + proto[0], proto[1])
        rows = []
        for idx in idxs:
            wh = self._wh(int(idx))
            try:
                row = wh.get(fld, int(idx))
            except KeyError:
                have = sorted(wh.store.get(fld, {}))
                raise KeyError(
                    f"transfer dock: field {fld!r} not ready for sample "
                    f"{int(idx)} (requested by worker state {state!r}; "
                    f"warehouse {wh.node} holds {fld!r} for samples "
                    f"{have[:8]}{'…' if len(have) > 8 else ''}). "
                    f"Did the producing stage run / mark this sample?"
                ) from None
            self.ledger.record(row.nbytes, cross=wh.node != dst_node,
                               node=wh.node)
            rows.append(row)
        return np.stack(rows)

    # -- metadata plane -----------------------------------------------------
    def request_metadata(self, state: str, fields, limit: int | None = None):
        ctl = self.controllers[state]
        # controller co-located with worker: the request's bytes are counted
        # but it is intranode, so it bears no RPC latency — msgs=0 (see
        # DispatchLedger.record_meta for the put-vs-get msgs contract)
        self.ledger.record_meta(META_PER_SAMPLE * META_SCALAR_BYTES, msgs=0)
        return ctl.available(fields, limit)

    def mark_consumed(self, state: str, idxs):
        self.controllers[state].consumed.update(int(i) for i in idxs)

    def clear(self):
        for wh in self.warehouses:
            wh.clear()
        for ctl in self.controllers.values():
            ctl.ready.clear()
            ctl.consumed.clear()


class CentralReplayBuffer(TransferDock):
    """Baseline: ONE warehouse on node 0, one shared controller on node 0 —
    every metadata request from a worker on node != 0 crosses the network."""

    name = "central_replay_buffer"

    def __init__(self, states: dict[str, int],
                 ledger: DispatchLedger | None = None, faults=None):
        super().__init__(1, states, ledger, faults=faults)
        self._states = states

    def request_metadata(self, state: str, fields, limit: int | None = None):
        ctl = self.controllers[state]
        cross = self._states[state] != 0
        self.ledger.record_meta(META_PER_SAMPLE * META_SCALAR_BYTES, msgs=1)
        if cross:
            self.ledger.record(META_PER_SAMPLE * META_SCALAR_BYTES, cross=True)
        return ctl.available(fields, limit)


# ---------------------------------------------------------------------------
# Analytic dispatch model — Eqs. (1), (2), (4) and Table 1 of the paper.
# ---------------------------------------------------------------------------

def cv_gb(G: int, N: int, B: int, PL: int, n: int, SL: int, M: int) -> float:
    """Eq. (1): one update-stage fetch, in GB."""
    return G * N * B * (PL + n * SL + M) / 1024 ** 3


def tcv_gb(G: int, N: int, B: int, PL: int, n: int, SL: int, M: int) -> float:
    """Eq. (2): total sample-flow volume of the last 3 pipeline steps, GB."""
    return G * N * B * (2 * PL + 3 * n * SL + 8 * M) / 1024 ** 3


def tcv_td_gb(G: int, N: int, B: int, PL: int, n: int, SL: int, M: int,
              C: int, S: int) -> float:
    """Eq. (4): per-warehouse volume under the transfer dock, GB."""
    return G * N * B * (2 * PL + 3 * n * SL + 8 * (C + 1) * M) / S / 1024 ** 3


def dispatch_time_s(volume_gb: float, bw_bytes_per_s: float) -> float:
    return volume_gb * 1024 ** 3 / bw_bytes_per_s
