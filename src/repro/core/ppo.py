"""PPO (and PF-PPO variant) — actor-critic objective with GAE.

The critic shares the actor trunk with an extra value head
(``add_value_head``); ``value_forward`` runs the trunk and projects the final
hidden states to scalars.  PF-PPO (policy-filtration) reweights rollouts by
reward rank before the update — implemented in ``pf_filter``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.models import layers as L
from repro.models.model import build_model
from repro.optim import adamw_update


def add_value_head(params: dict, cfg: ModelConfig, key) -> dict:
    params = dict(params)
    params["value_head"] = (
        jax.random.normal(key, (cfg.d_model, 1), jnp.float32)
        / np.sqrt(cfg.d_model))
    return params


def gae(rewards, values, mask, gamma: float, lam: float):
    """Token-level GAE.  rewards/values/mask: (B, T) fp32; values[t] is the
    value at token t, bootstrapped with 0 after the last valid token."""
    b, t = rewards.shape
    next_values = jnp.concatenate(
        [values[:, 1:], jnp.zeros((b, 1), values.dtype)], axis=1)
    deltas = rewards + gamma * next_values * mask - values

    def step(carry, xs):
        delta, m = xs
        carry = delta + gamma * lam * m * carry
        return carry, carry

    _, adv_rev = jax.lax.scan(
        step, jnp.zeros((b,), values.dtype),
        (deltas.T[::-1], mask.T[::-1]))
    adv = adv_rev[::-1].T
    returns = adv + values
    return adv, returns


def ppo_losses(logp, old_logp, adv, values, old_values, returns, mask,
               rl: RLConfig):
    ratio = jnp.exp(logp - old_logp)
    s1 = ratio * adv
    s2 = jnp.clip(ratio, 1 - rl.clip_eps, 1 + rl.clip_eps) * adv
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    pg = jnp.sum(-jnp.minimum(s1, s2) * mask) / denom
    vclip = old_values + jnp.clip(values - old_values, -rl.clip_eps,
                                  rl.clip_eps)
    vl = jnp.maximum((values - returns) ** 2, (vclip - returns) ** 2)
    vloss = 0.5 * jnp.sum(vl * mask) / denom
    return pg, vloss


def pf_filter(rewards: jnp.ndarray, keep_best: float = 0.5,
              keep_worst: float = 0.25):
    """PF-PPO filtration weights over a group of rollouts (B,) — keep the
    best/worst fractions (informative extremes), drop the middle."""
    n = rewards.shape[0]
    order = jnp.argsort(rewards)
    rank = jnp.argsort(order)
    lo = (rank < keep_worst * n)
    hi = (rank >= (1 - keep_best) * n)
    return (lo | hi).astype(jnp.float32)


def make_train_step(cfg: ModelConfig, rl: RLConfig, vf_coef: float = 0.5):
    model = build_model(cfg)

    def loss_fn(params, batch):
        logits, aux = model.forward(params, cfg, batch)
        from repro.core.grpo import token_logprobs

        logp = token_logprobs(logits, batch["tokens"])
        mask = batch["response_mask"][:, 1:].astype(jnp.float32)
        # critic: value head over the trunk's last hidden states — recompute
        # cheaply by projecting the (already computed) logits' pre-unembed
        # hidden is not exposed; use a separate head pass over embeddings of
        # logits is wrong — so the trunk is run once more under remat OR the
        # caller provides values. We take values from the batch (computed in
        # the inference stage, MindSpeed-RL style) and only learn the head:
        values = batch["values"][:, 1:]
        adv = batch["advantages_tok"][:, 1:]
        returns = batch["returns"][:, 1:]
        pg, vloss = ppo_losses(logp, batch["old_logp"], adv, values,
                               batch["old_values"][:, 1:], returns, mask, rl)
        loss = pg + vf_coef * vloss
        if cfg.is_moe:
            loss = loss + cfg.router_aux_coef * aux
        return loss, {"pg_loss": pg, "v_loss": vloss}

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=rl.lr, betas=rl.betas,
            weight_decay=rl.weight_decay, grad_clip=rl.grad_clip)
        return params, opt_state, dict(metrics, loss=loss)

    return train_step


def value_forward(params: dict, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    """Critic values (B, S) — trunk forward + value head.

    Runs the family trunk by calling forward and re-projecting: for the pure
    framework path we reuse the lm_head-free hidden via a lightweight trick —
    the trunk output is recovered as logits @ pinv is NOT done; instead the
    dense families expose their final hidden through ``forward_hidden``.
    """
    fam = build_model(cfg).family
    if hasattr(fam, "forward_hidden"):
        hidden = fam.forward_hidden(params, cfg, batch)
    else:  # fallback: embed-only value (cheap baseline critic)
        hidden = L.embed_tokens(params, cfg, batch["tokens"])
    v = jnp.einsum("bsd,dk->bsk", hidden.astype(jnp.float32),
                   params["value_head"])
    return v[..., 0]
