# The paper's primary contribution: distributed dataflow for RL training —
# the transfer dock (sample flow) + allgather-swap (resharding flow), plus
# the GRPO/PPO trainers and the generation engine that they orchestrate.
from repro.core import grpo, ppo  # noqa: F401
from repro.core.resharding import Resharder, naive_reshard  # noqa: F401
from repro.core.rollout import RolloutEngine  # noqa: F401
from repro.core.trainer import GRPOTrainer  # noqa: F401
from repro.core.transfer_dock import (  # noqa: F401
    CentralReplayBuffer,
    DispatchLedger,
    TransferDock,
)
