# The paper's primary contribution: distributed dataflow for RL training —
# the transfer dock (sample flow) + allgather-swap (resharding flow), and
# the first-class dataflow-graph API (RLGraph + GraphExecutor) that the
# GRPO/PPO/partial-rollout algorithm declarations run on.
from repro.core import grpo, ppo  # noqa: F401
from repro.core.graph import (  # noqa: F401
    GraphExecutor,
    RLGraph,
    StageNode,
    complete_groups,
    derive_nodes,
)
from repro.core.resharding import Resharder, naive_reshard  # noqa: F401
from repro.core.rollout import RolloutEngine  # noqa: F401
from repro.core.trainer import GRPOTrainer, build_grpo_graph  # noqa: F401
from repro.core.transfer_dock import (  # noqa: F401
    CentralReplayBuffer,
    DispatchLedger,
    TransferDock,
)
