"""Partial rollout (paper Table 2) — long-tail generation split across
iterations, declared as a graph over the SAME executor as GRPO/PPO.

Each iteration the generation node emits at most ``budget`` tokens per
sequence.  Sequences that hit EOS (or the total response cap) are FINISHED:
the node streams their rows into the dock and marks only them consumed, so
unfinished samples stay visible to the generation controller and resume
FIRST next iteration (re-prefilled under the then-current weights — the
mild off-policy prefix partial rollout accepts by design).  Downstream
nodes are the ordinary GRPO stages running GREEDILY (``expected=None``):
they fire on whatever finished, and the advantage node's ``complete_groups``
gate holds samples back until their whole GRPO group is present — the
dock's readiness metadata handles the cross-iteration wait for free, which
is exactly the paper's argument for a dataflow-level scheduler.
"""
from __future__ import annotations

from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grpo
from repro.core.graph import RLGraph, complete_groups, derive_nodes
from repro.core.trainer import (GRPOTrainer, IterationStats,  # noqa: F401
                                build_grpo_graph)


def build_partial_graph(actor_node: int = 0, ref_node: int = 1,
                        reward_node: int = 2) -> RLGraph:
    """Partial rollout as a graph EDIT of GRPO: a budgeted resume-generation
    node and a complete-group gate on the advantage node — not a trainer
    fork."""
    T = PartialRolloutTrainer
    base = build_grpo_graph(actor_node, ref_node, reward_node)
    return RLGraph("partial_rollout", derive_nodes(base, {
        "actor_generation": dict(fn=T._stage_generate),
        "advantages": dict(fn=T._stage_advantages,
                           gate=lambda ctx, idxs: complete_groups(
                               idxs, ctx.rl.num_generations)),
    }))


class PartialRolloutTrainer(GRPOTrainer):
    clear_dock_each_iteration = False   # indices persist across iterations

    def __init__(self, *args, budget: int = 8, **kw):
        self.budget = budget
        self.partials: dict[int, dict] = {}   # idx -> {tokens, ngen}
        self._next_idx = 0
        self._metas: dict[int, dict] = {}
        super().__init__(*args, **kw)

    def _build_graph(self) -> RLGraph:
        return build_partial_graph(self.actor.node, self.ref.node,
                                   self.reward.node)

    # -- enqueue: fresh prompts get persistent indices --------------------
    def _enqueue(self, global_batch: int) -> None:
        G, N = global_batch, self.rl.num_generations
        pl = self.rl.max_prompt_len
        self._plen = pl
        prompts, _, metas = self.dataset.sample(G)
        fresh, rows = [], []
        for i in range(G):
            for _ in range(N):
                idx = self._next_idx
                self._next_idx += 1
                self._metas[idx] = metas[i]
                row = np.full((pl,), self.tok.pad_id, np.int32)
                row[:] = prompts[i]
                self.partials[idx] = {"tokens": row, "ngen": 0}
                fresh.append(idx)
                rows.append(row)
        self.dock.put("prompt", fresh, np.stack(rows),
                      src_node=self.actor.node)
        return None        # greedy scheduling: stages run on what finishes

    # -- stage callables ---------------------------------------------------
    def _stage_generate(self, io):
        """Resume buckets of equal prefix length; ``io.idxs`` is every
        pending partial (unfinished samples were never marked consumed, so
        the controller keeps offering them)."""
        rl = self.rl
        pl = rl.max_prompt_len
        cap = pl + rl.max_response_len
        buckets = defaultdict(list)
        for idx in io.idxs:
            buckets[len(self.partials[idx]["tokens"])].append(idx)
        finished = []
        for plen, idxs in sorted(buckets.items()):
            batch = np.stack([self.partials[i]["tokens"] for i in idxs])
            self.key, k = jax.random.split(self.key)
            eng = self.actor.engine
            eng.max_new = self.budget
            roll = eng.generate(self.gen_params, batch, k)
            for j, idx in enumerate(idxs):
                st = self.partials[idx]
                n = int(roll.lengths[j])
                new_tokens = roll.tokens[j, plen:plen + n]
                st["tokens"] = np.concatenate([st["tokens"], new_tokens])
                st["ngen"] += n
                hit_eos = bool((new_tokens == self.tok.eos_id).any())
                if hit_eos or st["ngen"] >= rl.max_response_len:
                    row = np.full((cap,), self.tok.pad_id, np.int32)
                    row[:len(st["tokens"])] = st["tokens"][:cap]
                    mask = np.zeros((cap,), np.float32)
                    mask[pl:pl + st["ngen"]] = 1.0
                    io.put("tokens", [idx], row[None])
                    io.put("response_mask", [idx], mask[None])
                    finished.append(idx)
                    del self.partials[idx]
        io.consumed = finished
        return None

    def _stage_advantages(self, io):
        """Group z-scores over COMPLETE groups only (the gate guarantees
        ``io.idxs`` is a union of whole groups, sorted)."""
        N = self.rl.num_generations
        rw = io.ins["rewards"][:, 0]
        adv = np.asarray(
            grpo.group_advantages(jnp.asarray(rw.reshape(-1, N)))
        ).reshape(-1)
        return {"advantages": adv[:, None]}

    @property
    def pending_partials(self) -> int:
        return len(self.partials)
