"""Partial rollout (paper Table 2) — long-tail generation split across
iterations.

Each iteration the actor generates at most ``budget`` tokens per sequence.
Sequences that emit EOS (or exhaust the total response cap) are FINISHED and
flow to inference/update through the transfer dock; the rest are stashed in
the dock as partials and resumed FIRST next iteration (re-prefilled under the
then-current weights — the mild off-policy prefix that partial rollout
accepts by design).  GRPO group advantages are computed per COMPLETE group
only, so groups whose members span iterations simply wait in the warehouses —
the dock's readiness metadata handles this for free, which is exactly the
paper's argument for a dataflow-level scheduler.
"""
from __future__ import annotations

import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grpo
from repro.core.trainer import GRPOTrainer, IterationStats


class PartialRolloutTrainer(GRPOTrainer):
    def __init__(self, *args, budget: int = 8, **kw):
        super().__init__(*args, **kw)
        self.budget = budget
        self.partials: dict[int, dict] = {}   # idx -> {tokens, ngen}
        self._next_idx = 0
        self._meta: dict[int, dict] = {}
        self._group_rewards: dict[int, dict[int, float]] = defaultdict(dict)

    # -- helpers --------------------------------------------------------
    def _finish(self, idx: int, tokens_row: np.ndarray, ngen: int, pl: int):
        cap = pl + self.rl.max_response_len
        row = np.full((cap,), self.tok.pad_id, np.int32)
        row[:len(tokens_row)] = tokens_row[:cap]
        mask = np.zeros((cap,), np.float32)
        mask[pl:pl + ngen] = 1.0
        self.dock.put("tokens", [idx], row[None], src_node=0)
        self.dock.put("response_mask", [idx], mask[None], src_node=0)

    # -- main loop ------------------------------------------------------
    def iteration(self, global_batch: int) -> IterationStats:
        cfg, rl = self.cfg, self.rl
        G, N = global_batch, rl.num_generations
        pl = rl.max_prompt_len

        # enqueue fresh prompts (persistent indices across iterations)
        prompts, _, metas = self.dataset.sample(G)
        fresh = []
        for i in range(G):
            for _ in range(N):
                idx = self._next_idx
                self._next_idx += 1
                self._meta[idx] = metas[i]
                row = np.full((pl,), self.tok.pad_id, np.int32)
                row[:] = prompts[i]
                self.partials[idx] = {"tokens": row, "ngen": 0}
                fresh.append(idx)

        gen_params, stash, reshard_led = self.resharder.to_generation(
            self.params)
        del self.params

        # ---- generation stage: resume buckets of equal prefix length ----
        t0 = time.perf_counter()
        buckets = defaultdict(list)
        for idx, st in self.partials.items():
            buckets[len(st["tokens"])].append(idx)
        finished = []
        for plen, idxs in sorted(buckets.items()):
            batch = np.stack([self.partials[i]["tokens"] for i in idxs])
            self.key, k = jax.random.split(self.key)
            eng = self.actor.engine
            eng.max_new = self.budget
            roll = eng.generate(gen_params, batch, k)
            for j, idx in enumerate(idxs):
                st = self.partials[idx]
                n = int(roll.lengths[j])
                new_tokens = roll.tokens[j, plen:plen + n]
                st["tokens"] = np.concatenate([st["tokens"], new_tokens])
                st["ngen"] += n
                hit_eos = bool((new_tokens == self.tok.eos_id).any())
                done = hit_eos or st["ngen"] >= rl.max_response_len
                if done:
                    self._finish(idx, st["tokens"], st["ngen"], pl)
                    finished.append(idx)
                    del self.partials[idx]
        gen_time = time.perf_counter() - t0
        del gen_params
        self.params, reshard_led = self.resharder.to_update(stash, reshard_led)

        # ---- inference + reward on finished samples ---------------------
        t0 = time.perf_counter()
        rewards_seen = []
        if finished:
            toks = self.dock.get("actor_inference", "tokens", finished, 0)
            old_logp = self.actor.old_logprobs(self.params, toks)
            self.dock.put("old_logp", finished, old_logp, src_node=0)
            ref_logp = self.ref.logprobs(toks)
            self.dock.put("ref_logp", finished, ref_logp,
                          src_node=self.ref.node)
            rw = self.reward.score([self._meta[i] for i in finished], toks, pl)
            rewards_seen = list(rw)
            for idx, r in zip(finished, rw):
                self._group_rewards[idx // N][idx] = float(r)

        # advantages for COMPLETE groups only
        ready_updates = []
        for gid, members in list(self._group_rewards.items()):
            if len(members) == N:
                rs = np.array([members[i] for i in sorted(members)],
                              np.float32)
                adv = np.asarray(
                    grpo.group_advantages(jnp.asarray(rs[None]))).reshape(-1)
                idxs = sorted(members)
                self.dock.put("advantages", idxs, adv[:, None], src_node=0)
                ready_updates.extend(idxs)
                del self._group_rewards[gid]
        infer_time = time.perf_counter() - t0

        # ---- update stage -----------------------------------------------
        t0 = time.perf_counter()
        losses, kls = [], []
        if ready_updates:
            sel = ready_updates
            batch = {
                "tokens": jnp.asarray(self.dock.get(
                    "actor_update", "tokens", sel, 0)),
                "response_mask": jnp.asarray(self.dock.get(
                    "actor_update", "response_mask", sel, 0)),
                "old_logp": jnp.asarray(self.dock.get(
                    "actor_update", "old_logp", sel, 0)),
                "ref_logp": jnp.asarray(self.dock.get(
                    "actor_update", "ref_logp", sel, 0)),
                "advantages": jnp.asarray(self.dock.get(
                    "actor_update", "advantages", sel, 0))[:, 0],
            }
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            losses.append(float(metrics["loss"]))
            kls.append(float(metrics["kl"]))
            self.dock.mark_consumed("actor_update", sel)
        update_time = time.perf_counter() - t0

        return IterationStats(
            reward_mean=float(np.mean(rewards_seen)) if rewards_seen else 0.0,
            reward_std=float(np.std(rewards_seen)) if rewards_seen else 0.0,
            loss=float(np.mean(losses)) if losses else 0.0,
            kl=float(np.mean(kls)) if kls else 0.0,
            gen_time=gen_time, infer_time=infer_time, update_time=update_time,
            reshard=reshard_led.snapshot(),
            dispatch=self.dock.ledger.snapshot(),
        )

    @property
    def pending_partials(self) -> int:
        return len(self.partials)
