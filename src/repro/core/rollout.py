"""Synchronized batch generation engine (the serving-free baseline).

Batched synchronized decode: one jitted prefill over the padded prompts, then
a host loop of jitted single-token steps with donated cache (in-place on
device).  Sampling is temperature/greedy with per-sequence EOS stopping.
Every sequence in the batch decodes until the SLOWEST finishes — the
request-level continuous-batching engine (``repro.serve``, the vLLM-Ascend
analogue) exists to remove exactly that barrier, and under greedy decoding
it must reproduce this engine's outputs BIT-for-bit, which makes this the
serving subsystem's correctness oracle.

The engine operates on whatever weight layout ``core/resharding.py`` produced
for the generation stage — weights and cache are never copied host-side here.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import build_model


@dataclass
class RolloutResult:
    tokens: np.ndarray          # (B, prompt+new) int32, PAD after EOS
    response_mask: np.ndarray   # (B, prompt+new) 1.0 on generated tokens
    gen_logp: np.ndarray        # (B, new) logp of sampled tokens (engine-side)
    lengths: np.ndarray         # (B,) #generated tokens (incl. EOS)


def sample_tokens(logits, key, *, temperature: float, greedy: bool,
                  done=None, pad_id: int = 0):
    """THE sampling arithmetic — every generation engine (sync rollout and
    serve.ServingEngine) must route through here: the serving engine's
    bit-compatibility contract with this engine holds only while the two
    sample identically.  Returns (next_token int32, its logp)."""
    logits = logits / max(temperature, 1e-6)
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    if greedy:
        nxt = jnp.argmax(logits, axis=-1)
    else:
        nxt = jax.random.categorical(key, logits, axis=-1)
    lp = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]
    if done is not None:
        nxt = jnp.where(done, pad_id, nxt)
        lp = jnp.where(done, 0.0, lp)
    return nxt.astype(jnp.int32), lp


class RolloutEngine:
    def __init__(self, cfg: ModelConfig, *, max_new: int, eos_id: int,
                 pad_id: int, temperature: float = 1.0, greedy: bool = False):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_new = max_new
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.temperature = temperature
        self.greedy = greedy
        self._prefill = jax.jit(self._prefill_impl)
        self._step = jax.jit(self._step_impl, donate_argnums=(1,))

    # -- jitted pieces ------------------------------------------------------
    def _prefill_impl(self, params, batch, cache):
        return self.model.prefill(params, self.cfg, batch, cache)

    def _step_impl(self, params, cache, tok, pos, key, done):
        logits, cache = self.model.decode(params, self.cfg, cache, tok, pos)
        nxt, lp = sample_tokens(logits, key, temperature=self.temperature,
                                greedy=self.greedy, done=done,
                                pad_id=self.pad_id)
        done = done | (nxt == self.eos_id)
        return cache, nxt, lp, done

    # -- public API ---------------------------------------------------------
    def generate(self, params, prompts: np.ndarray, key,
                 extras: dict | None = None) -> RolloutResult:
        """prompts: (B, PL) int32 padded.  Synchronized batch decode."""
        cfg = self.cfg
        b, pl = prompts.shape
        cap = pl + self.max_new
        cache = self.model.init_cache(cfg, b, cap)
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update(extras)
        logits, cache = self._prefill(params, batch, cache)

        key, k0 = jax.random.split(key)
        tok, lp = sample_tokens(logits, k0, temperature=self.temperature,
                                greedy=self.greedy)
        done = tok == self.eos_id
        toks = [np.asarray(tok, np.int32)]
        lps = [np.asarray(lp, np.float32)]

        for t in range(1, self.max_new):
            key, k = jax.random.split(key)
            cache, tok, lp, done = self._step(
                params, cache, tok[:, None], jnp.int32(pl + t - 1), k, done)
            toks.append(np.asarray(tok, np.int32))
            lps.append(np.asarray(lp, np.float32))
            if bool(np.all(np.asarray(done))):
                break

    # -- assemble host-side result ------------------------------------------
        gen = np.stack(toks, axis=1)                        # (B, T)
        gen_logp = np.stack(lps, axis=1)
        tconc = np.full((b, cap), self.pad_id, np.int32)
        tconc[:, :pl] = prompts
        tconc[:, pl:pl + gen.shape[1]] = gen
        mask = np.zeros((b, cap), np.float32)
        lengths = np.zeros((b,), np.int32)
        for i in range(b):
            row = gen[i]
            stop = np.where(row == self.eos_id)[0]
            n = (stop[0] + 1) if len(stop) else gen.shape[1]
            mask[i, pl:pl + n] = 1.0
            lengths[i] = n
            tconc[i, pl + n:] = self.pad_id
        return RolloutResult(tokens=tconc, response_mask=mask,
                             gen_logp=gen_logp, lengths=lengths)


@functools.lru_cache(maxsize=8)
def _engine_cache(cfg, max_new, eos, pad, temp, greedy):
    return RolloutEngine(cfg, max_new=max_new, eos_id=eos, pad_id=pad,
                         temperature=temp, greedy=greedy)
