"""Synchronized batch generation engine (the serving-free baseline).

Batched synchronized decode: one jitted prefill over the padded prompts, then
a host loop of jitted single-token steps with donated cache (in-place on
device).  Every sequence in the batch decodes until the SLOWEST finishes —
the request-level continuous-batching engine (``repro.serve``, the
vLLM-Ascend analogue) exists to remove exactly that barrier, and it must
reproduce this engine's outputs BIT-for-bit, which makes this the serving
subsystem's correctness oracle.

Sampling is COUNTER-BASED per sequence: token ``t`` of row ``i`` is drawn
with ``fold_in(fold_in(key, i), t)`` (``request_stream`` + ``token_keys``),
never from an engine-wide key chain — so a sequence's sampled tokens are a
pure function of (params, prompt, stream, t), independent of batch
composition or how the serving engine schedules it.  The serving engine
derives the SAME streams (rid ↔ row index), which is what extends the
greedy bit-identity contract to temperature/top-p/top-k sampling.

The engine operates on whatever weight layout ``core/resharding.py`` produced
for the generation stage — weights and cache are never copied host-side here.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import build_model


@dataclass
class RolloutResult:
    tokens: np.ndarray          # (B, prompt+new) int32, PAD after EOS
    response_mask: np.ndarray   # (B, prompt+new) 1.0 on generated tokens
    gen_logp: np.ndarray        # (B, new) logp of sampled tokens (engine-side)
    lengths: np.ndarray         # (B,) #generated tokens (incl. EOS)


def request_stream(base_key, seed: int):
    """Root key of one request's sampling stream: ``fold_in(base_key, seed)``.

    THE stream derivation — both engines route through here so that a
    request keyed by the same (base_key, seed) samples the same tokens in
    either engine, under any schedule.  ``seed`` is the request's stable
    identity: the sync engine uses the batch row index, the serving engine
    uses the request id (or an explicit ``submit(seed=...)``)."""
    return jax.random.fold_in(base_key, seed)


def token_keys(streams, t):
    """Per-row sampling keys for token index ``t`` of each stream.

    streams: (B, 2) uint32 stream roots; t: scalar or (B,) int32 token
    index (the count of tokens generated before this one).  Vectorized
    ``fold_in`` — row ``i`` gets exactly the bits a standalone
    ``fold_in(streams[i], t[i])`` produces, so the result is independent
    of which other rows share the batch."""
    streams = jnp.asarray(streams)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (streams.shape[0],))
    return jax.vmap(jax.random.fold_in)(streams, t)


def truncate_logits(logits, *, top_p: float = 1.0, top_k: int = 0):
    """Fused top-k/top-p (nucleus) truncation: logits outside the kept set
    become ``-inf`` so a downstream categorical renormalizes over exactly
    the survivors.  ``top_p=1.0`` and ``top_k=0`` are no-ops (the input is
    returned untouched — bit-exact plain temperature sampling).

    Deterministic tie-breaking: candidates are ranked by one STABLE
    descending sort, so equal logits rank lower-token-id first, and both
    cutoffs (rank < top_k; exclusive cumulative mass < top_p, computed
    after the top-k mask renormalizes) cut on that same ranking.  The
    top-p set is the smallest prefix whose mass reaches ``top_p`` (rank 0
    always survives)."""
    if top_p >= 1.0 and top_k <= 0:
        return logits
    if not 0.0 < top_p <= 1.0:
        raise ValueError(f"top_p must be in (0, 1], got {top_p}")
    if top_k < 0:
        raise ValueError(f"top_k must be >= 0, got {top_k}")
    order = jnp.argsort(-logits, axis=-1, stable=True)   # desc, ties by id
    ranked = jnp.take_along_axis(logits, order, axis=-1)
    keep = jnp.ones(ranked.shape, bool)
    if top_k > 0:
        keep &= jnp.arange(ranked.shape[-1]) < top_k
        ranked = jnp.where(keep, ranked, -jnp.inf)
    if top_p < 1.0:
        probs = jax.nn.softmax(ranked, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep &= (cum - probs) < top_p          # exclusive mass below cutoff
    inv = jnp.argsort(order, axis=-1, stable=True)
    keep = jnp.take_along_axis(keep, inv, axis=-1)
    return jnp.where(keep, logits, -jnp.inf)


def sample_tokens(logits, key, *, temperature: float, greedy: bool,
                  top_p: float = 1.0, top_k: int = 0, done=None,
                  pad_id: int = 0):
    """THE sampling arithmetic — every generation engine (sync rollout and
    serve.ServingEngine) must route through here: the serving engine's
    bit-compatibility contract with this engine holds only while the two
    sample identically.  Returns (next_token int32, its logp).

    ``key`` is either one key (2,) shared across the batch (legacy) or a
    (B, 2) batch of PER-ROW keys (``token_keys``); with per-row keys, row
    ``i``'s draw depends only on (key[i], logits[i]) — batch-composition
    independent, the property the serving invariance contract rests on.
    ``top_p``/``top_k`` truncate the candidate set (``truncate_logits``)
    before the draw; the returned logp is always the token's logp under
    the UN-truncated temperature-scaled distribution — the policy logp RL
    importance ratios need — so truncation changes which token is drawn,
    never how a drawn token is scored.  ``greedy=True`` ignores key and
    truncation entirely (argmax; the degenerate case all pre-sampling
    bitwise contracts pin)."""
    logits = logits / max(temperature, 1e-6)
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    if greedy:
        nxt = jnp.argmax(logits, axis=-1)
    else:
        filt = truncate_logits(logits, top_p=top_p, top_k=top_k)
        key = jnp.asarray(key)
        if key.ndim == 2:                  # per-row streams
            nxt = jax.vmap(jax.random.categorical)(key, filt)
        else:
            nxt = jax.random.categorical(key, filt, axis=-1)
    lp = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]
    if done is not None:
        nxt = jnp.where(done, pad_id, nxt)
        lp = jnp.where(done, 0.0, lp)
    return nxt.astype(jnp.int32), lp


@functools.lru_cache(maxsize=32)
def sampled_drawer(temperature: float, top_p: float, top_k: int,
                   pad_id: int):
    """THE shared sampled-token drawer: one jitted
    ``(logits, streams, t, done) -> (token, logp)`` callable per sampling
    configuration, used by EVERY engine in the process.  Routing both the
    sync and the serving engine through the SAME compiled function (on
    logits they each computed bitwise-equally) is what makes sampled
    tokens AND their logp bitwise equal across engines: were the draw
    fused into each engine's own step jit, XLA could reassociate the
    ``log_softmax`` reduction differently per graph and drift the logp by
    ulps.  ``done`` rows draw pad/0.0 (idle serving slots, finished sync
    rows); first-token callers pass all-False."""
    def fn(logits, streams, t, done):
        return sample_tokens(logits, token_keys(streams, t),
                             temperature=temperature, greedy=False,
                             top_p=top_p, top_k=top_k, done=done,
                             pad_id=pad_id)
    return jax.jit(fn)


class RolloutEngine:
    def __init__(self, cfg: ModelConfig, *, max_new: int, eos_id: int,
                 pad_id: int, temperature: float = 1.0, greedy: bool = False,
                 top_p: float = 1.0, top_k: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_new = max_new
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.temperature = temperature
        self.greedy = greedy
        self.top_p = top_p
        self.top_k = top_k
        self._prefill = jax.jit(self._prefill_impl)
        # greedy keeps sampling FUSED into the step jit (the pre-streams
        # graph — argmax consumes no key, so the stream args trace away and
        # existing greedy bit-contracts are untouched); sampled mode steps
        # to logits only and draws through the process-shared drawer
        self._step = jax.jit(self._step_impl, donate_argnums=(1,))
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._draw = (None if greedy else
                      sampled_drawer(temperature, top_p, top_k, pad_id))

    # -- jitted pieces ------------------------------------------------------
    def _prefill_impl(self, params, batch, cache):
        return self.model.prefill(params, self.cfg, batch, cache)

    def _step_impl(self, params, cache, tok, pos, done):
        """Greedy fused step: decode + argmax + done fold in one graph."""
        logits, cache = self.model.decode(params, self.cfg, cache, tok, pos)
        nxt, lp = sample_tokens(logits, None, temperature=self.temperature,
                                greedy=True, done=done, pad_id=self.pad_id)
        done = done | (nxt == self.eos_id)
        return cache, nxt, lp, done

    def _decode_impl(self, params, cache, tok, pos):
        """Sampled-mode step: logits only — the draw happens in the shared
        ``sampled_drawer`` so it is bitwise engine-independent."""
        return self.model.decode(params, self.cfg, cache, tok, pos)

    # -- public API ---------------------------------------------------------
    def generate(self, params, prompts: np.ndarray, key,
                 extras: dict | None = None) -> RolloutResult:
        """prompts: (B, PL) int32 padded.  Synchronized batch decode.

        ``key`` is consumed as the RUN key only: row ``i`` samples token
        ``t`` with ``fold_in(fold_in(key, i), t)``, so each row's token
        sequence is independent of every other row (and replayable by the
        serving engine from the same key)."""
        cfg = self.cfg
        b, pl = prompts.shape
        cap = pl + self.max_new
        cache = self.model.init_cache(cfg, b, cap)
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update(extras)
        logits, cache = self._prefill(params, batch, cache)

        streams = jax.vmap(lambda i: request_stream(key, i))(jnp.arange(b))
        nodone = jnp.zeros((b,), bool)
        if self.greedy:
            tok, lp = sample_tokens(logits, None,
                                    temperature=self.temperature, greedy=True)
        else:
            tok, lp = self._draw(logits, streams, jnp.zeros((b,), jnp.int32),
                                 nodone)
        done = tok == self.eos_id
        toks = [np.asarray(tok, np.int32)]
        lps = [np.asarray(lp, np.float32)]

        for t in range(1, self.max_new):
            if self.greedy:
                cache, tok, lp, done = self._step(
                    params, cache, tok[:, None], jnp.int32(pl + t - 1), done)
            else:
                logits, cache = self._decode(params, cache, tok[:, None],
                                             jnp.int32(pl + t - 1))
                tok, lp = self._draw(logits, streams,
                                     jnp.full((b,), t, jnp.int32), done)
                done = done | (tok == self.eos_id)
            toks.append(np.asarray(tok, np.int32))
            lps.append(np.asarray(lp, np.float32))
            if bool(np.all(np.asarray(done))):
                break

    # -- assemble host-side result ------------------------------------------
        gen = np.stack(toks, axis=1)                        # (B, T)
        gen_logp = np.stack(lps, axis=1)
        tconc = np.full((b, cap), self.pad_id, np.int32)
        tconc[:, :pl] = prompts
        tconc[:, pl:pl + gen.shape[1]] = gen
        mask = np.zeros((b, cap), np.float32)
        lengths = np.zeros((b,), np.int32)
        for i in range(b):
            row = gen[i]
            stop = np.where(row == self.eos_id)[0]
            n = (stop[0] + 1) if len(stop) else gen.shape[1]
            mask[i, pl:pl + n] = 1.0
            lengths[i] = n
            tconc[i, pl + n:] = self.pad_id
        return RolloutResult(tokens=tconc, response_mask=mask,
                             gen_logp=gen_logp, lengths=lengths)


@functools.lru_cache(maxsize=8)
def _engine_cache(cfg, max_new, eos, pad, temp, greedy, top_p=1.0, top_k=0):
    return RolloutEngine(cfg, max_new=max_new, eos_id=eos, pad_id=pad,
                         temperature=temp, greedy=greedy, top_p=top_p,
                         top_k=top_k)
