"""First-class dataflow-graph API — RL algorithms as declared graphs.

The paper (Fig. 1) describes RL training as a graph whose NODES are worker
states and whose EDGES are sample dataflow through the transfer dock plus
the weight resharding flow.  This module makes that graph a first-class
object instead of hand-written stage sequencing inside each trainer:

  * ``StageNode``     — one worker state: its cluster node id, the dock
    fields it consumes/produces, the callable that does the work, and an
    optional weight-layout requirement ("generation" | "update") which IS
    the resharding-flow edge.
  * ``RLGraph``       — a validated collection of stage nodes (unique
    names, acyclic field dependencies, every input produced by some node
    or declared external).
  * ``GraphExecutor`` — the readiness-driven scheduler: it runs any node
    whose input fields are ready per the TDController metadata, performs
    the resharding transitions the layout edges demand, and — when the
    config enables stage fusion — dispatches independent ready nodes
    CONCURRENTLY (the paper's Table 2 fusion becomes a scheduling
    property, not trainer code).

Mapping of paper Fig. 1 onto a GRPO declaration::

                       +------------------+
        prompt ------> | actor_generation |   layout: generation
                       +------------------+
                         | tokens, response_mask
          +--------------+---------------+----------------+
          v                              v                v
    [actor_inference]            [ref_inference]      [reward]     (all three
      | old_logp                   | ref_logp           | rewards   fuse)
          +--------------+---------------+        +-----+
                         v                        v
                         |                  [advantages]  (group barrier)
                         |                        | advantages
                         +-----------+------------+
                                     v
                              [actor_update]          layout: update

With the serving engine, generation streams each finished sample into the
dock the moment its sequence completes; the executor polls the metadata
plane while generation drains and starts stream-capable downstream nodes
(ref_inference, reward) at SAMPLE granularity — before the generation
barrier.

Execution semantics
-------------------
``GraphExecutor.run(graph, ctx, expected=N)`` schedules in rounds.  In each
round every node not yet finished asks its controller which samples have
all declared input fields ready; a node with work is dispatched when

  * it is a STREAM node (``stream=True``) — any non-empty subset runs, or
  * it is a BARRIER node — the full expected batch must be ready
    (``expected`` is the per-iteration sample count; ``expected=None``
    makes every node greedy, which is what partial rollout needs).

All runnable nodes of one round that agree on a weight layout are
dispatched together — concurrently when ``rl.stage_fusion`` is set.  The
executor owns the resharding flow: before dispatching a round it moves the
actor weights to the layout the round requires via
``ctx.resharder.to_generation()`` / ``to_update()`` and restores the update
layout when the run drains.  Node callables never call the resharder.

``ctx`` is the algorithm object (a trainer).  The executor reads/writes
``ctx.params`` (update-layout weights) and ``ctx.gen_params``
(generation-layout weights, only non-None while the generation layout is
live) and reads ``ctx.resharder``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.core.resharding import ReshardLedger
from repro.obs import MetricsRegistry, get_tracer
from repro.resilience import RetryPolicy, TransientError

LAYOUTS = ("generation", "update")
TIMINGS = ("gen", "infer", "update")


@dataclass
class StageNode:
    """One worker state of the RL dataflow graph.

    ``fn(ctx, io)`` does the stage's work: ``io.ins`` holds the fetched
    input fields (stacked arrays over ``io.idxs``), and the return value is
    a dict ``{field: rows}`` aligned with ``io.idxs`` that the executor
    puts back into the dock (return None to opt out — e.g. when the stage
    streamed its outputs through ``io.put`` itself).  Setting
    ``io.consumed`` to a subset of ``io.idxs`` marks only those samples
    consumed (partial rollout finishes a prefix of its batch per round).
    """
    name: str                         # worker-state name (one TDController)
    node: int                         # cluster node id (dock ledger routing)
    inputs: tuple                     # dock fields consumed
    outputs: tuple                    # dock fields produced
    fn: Callable                      # fn(ctx, io) -> dict | None
    layout: Optional[str] = None      # "generation" | "update" | None (any)
    stream: bool = False              # may run on partial sample subsets
    gate: Optional[Callable] = None   # gate(ctx, idxs) -> dispatchable idxs
    timing: str = "infer"             # stats bucket: gen | infer | update
    max_retries: Optional[int] = None  # transient-failure retry budget for
    #                                    this node (None = executor default)

    def __post_init__(self):
        if self.layout is not None and self.layout not in LAYOUTS:
            raise ValueError(f"node {self.name!r}: layout must be one of "
                             f"{LAYOUTS}, got {self.layout!r}")
        if self.timing not in TIMINGS:
            raise ValueError(f"node {self.name!r}: timing must be one of "
                             f"{TIMINGS}, got {self.timing!r}")
        self.inputs = tuple(self.inputs)
        self.outputs = tuple(self.outputs)


class StageIO:
    """Per-dispatch view handed to a node callable."""

    def __init__(self, node: StageNode, idxs: list, ins: dict,
                 executor: "GraphExecutor"):
        self.node = node
        self.idxs = list(idxs)
        self.ins = ins
        self.consumed = list(idxs)    # fn may shrink (partial rollout)
        self._ex = executor

    def put(self, fld: str, idxs, rows) -> None:
        """Thread-safe dock put attributed to this stage's cluster node —
        used by streaming stages (serving on_finish) to emit per-sample
        outputs before the stage returns."""
        self._ex.put(self.node, fld, idxs, rows)


class RLGraph:
    """A validated dataflow graph: stage nodes + field edges."""

    def __init__(self, name: str, nodes: Sequence[StageNode],
                 external: Sequence[str] = ("prompt",)):
        self.name = name
        self.nodes = list(nodes)
        self.external = tuple(external)
        self._validate()

    # -- validation ---------------------------------------------------------
    def _validate(self) -> None:
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"graph {self.name!r}: duplicate node names in "
                             f"{names}")
        producers: dict[str, str] = {}
        for n in self.nodes:
            for f in n.outputs:
                if f in producers:
                    raise ValueError(
                        f"graph {self.name!r}: field {f!r} produced by both "
                        f"{producers[f]!r} and {n.name!r}")
                producers[f] = n.name
        for n in self.nodes:
            for f in n.inputs:
                if f not in producers and f not in self.external:
                    raise ValueError(
                        f"graph {self.name!r}: node {n.name!r} consumes "
                        f"{f!r} which no node produces and which is not "
                        f"declared external {self.external}")
        self.toposort()   # raises on cycles

    def toposort(self) -> list:
        """Topological order over field dependencies (Kahn).  Raises on
        cycles.  The declared order is preserved among ties — it is the
        deterministic dispatch order of the executor."""
        producers = {f: n.name for n in self.nodes for f in n.outputs}
        deps = {n.name: {producers[f] for f in n.inputs if f in producers
                         and producers[f] != n.name}
                for n in self.nodes}
        order, placed = [], set()
        nodes = list(self.nodes)
        while nodes:
            ready = [n for n in nodes if deps[n.name] <= placed]
            if not ready:
                cyc = sorted(n.name for n in nodes)
                raise ValueError(f"graph {self.name!r}: dependency cycle "
                                 f"among {cyc}")
            for n in ready:
                order.append(n)
                placed.add(n.name)
            nodes = [n for n in nodes if n.name not in placed]
        return order

    # -- derived views ------------------------------------------------------
    def states(self) -> dict:
        """worker-state name -> cluster node id (the TransferDock ctor arg)."""
        return {n.name: n.node for n in self.nodes}

    def edges(self) -> list:
        """(producer, field, consumer) triples, external producers as '·'."""
        producers = {f: n.name for n in self.nodes for f in n.outputs}
        out = []
        for n in self.nodes:
            for f in n.inputs:
                out.append((producers.get(f, "·"), f, n.name))
        return out

    def describe(self) -> str:
        """Human-readable declaration — what `--print-graph` shows."""
        lines = [f"RLGraph {self.name!r} "
                 f"(external fields: {', '.join(self.external)})"]
        for n in self.toposort():
            tags = []
            if n.layout:
                tags.append(f"layout={n.layout}")
            if n.stream:
                tags.append("stream")
            if n.gate is not None:
                tags.append("gated")
            tag = f"  [{', '.join(tags)}]" if tags else ""
            lines.append(f"  {n.name} @node{n.node}{tag}")
            lines.append(f"      in : {', '.join(n.inputs) or '—'}")
            lines.append(f"      out: {', '.join(n.outputs) or '—'}")
        return "\n".join(lines)


@dataclass
class GraphRun:
    """Result record of one GraphExecutor.run."""
    trace: list = field(default_factory=list)        # (node, idxs) dispatches
    stage_times: dict = field(default_factory=lambda: dict.fromkeys(
        TIMINGS, 0.0))
    counts: dict = field(default_factory=dict)       # node -> samples consumed
    rounds: int = 0
    reshard: ReshardLedger = field(default_factory=ReshardLedger)
    retries: dict = field(default_factory=dict)      # node -> retry count
    quarantined: dict = field(default_factory=dict)  # node -> dropped idxs
    quarantined_idxs: set = field(default_factory=set)  # union over nodes


class GraphExecutor:
    """Readiness-driven scheduler over one transfer dock.

    One executor instance serves ANY RLGraph over its dock — GRPO, PPO and
    partial rollout are three declarations over the same engine.
    """

    def __init__(self, dock, rl, tracer=None, faults=None, retry=None,
                 metrics=None):
        self.dock = dock  # guarded-by: lock
        self.rl = rl
        self.lock = threading.RLock()
        # every dispatch emits one `stage.<node>` span (cat "graph") carrying
        # node id, sample idxs and fused-round membership — the rich form of
        # the (node, idxs) tuples GraphRun.trace keeps for bit-identity tests
        self.tracer = tracer if tracer is not None else get_tracer()
        self.faults = faults              # FaultPlan | None (chaos hook)
        self.retry = retry if retry is not None else RetryPolicy()
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    # -- thread-safe dock access -------------------------------------------
    def put(self, node: StageNode, fld: str, idxs, rows) -> None:
        # dock.put injects its fault at entry, before any row lands, so a
        # retried put is exactly idempotent (same rows land once)
        for attempt in range(self.retry.max_retries + 1):
            try:
                with self.lock:
                    self.dock.put(fld, idxs, rows, src_node=node.node)
                return
            except TransientError as err:
                if attempt >= self.retry.max_retries:
                    raise
                self._note_retry(node, attempt, err)
                time.sleep(self.retry.backoff(attempt))

    def _available(self, node: StageNode, ctx) -> list:
        with self.lock:
            idxs = self.dock.request_metadata(node.name, node.inputs)
        if node.gate is not None:
            idxs = list(node.gate(ctx, idxs))
        return idxs

    def _peek(self, node: StageNode, ctx) -> list:
        """Readiness check WITHOUT a ledger-counted metadata request — the
        streaming busy-poll uses this so the dispatch ledger keeps modeling
        algorithmic traffic, not poll frequency (a real deployment is
        notified by the warehouse broadcast, not by polling)."""
        with self.lock:
            idxs = self.dock.controllers[node.name].available(node.inputs)
        if node.gate is not None:
            idxs = list(node.gate(ctx, idxs))
        return idxs

    def _fetch(self, node: StageNode, idxs) -> dict:
        with self.lock:
            return {f: self.dock.get(node.name, f, idxs, node.node)
                    for f in node.inputs}

    # -- layout (resharding-flow) edges -------------------------------------
    def _ensure_layout(self, ctx, want: str) -> None:
        if want == self._layout:
            return
        if not self.tracer.enabled:   # disabled tracer: no span-name f-string
            return self._do_reshard(ctx, want)
        with self.tracer.span(f"reshard.to_{want}", cat="reshard"):
            self._do_reshard(ctx, want)

    def _do_reshard(self, ctx, want: str) -> None:
        if want == "generation":
            gen, stash, led = ctx.resharder.to_generation(ctx.params)
            ctx.params = None     # paper semantics: update buffers off-device
            ctx.gen_params = gen
            self._stash = stash
            # accumulate across round trips so multi-transition runs report
            # total reshard traffic, not just the last trip
            prev = self._run.reshard
            led.events = prev.events + led.events
            led.d2h_bytes += prev.d2h_bytes
            led.h2d_bytes += prev.h2d_bytes
            led.gathered_bytes += prev.gathered_bytes
            led.wall_s += prev.wall_s
            self._run.reshard = led
        else:
            ctx.gen_params = None
            ctx.params, self._run.reshard = ctx.resharder.to_update(
                self._stash, self._run.reshard)
            self._stash = None
        self._layout = want

    # -- dispatch -----------------------------------------------------------
    def _dispatch(self, node: StageNode, idxs, ctx, *, round_: int = 0,
                  fused: bool = False, stream: bool = False) -> None:
        """One stage dispatch.  ``round_`` is the executor round that
        scheduled it, ``fused`` whether it shared the round with other
        nodes (concurrent dispatch), ``stream`` whether it was started by
        the streaming poll while a generation stage drained — together the
        span records the fused-round membership the bare trace tuple
        cannot express."""
        if not self.tracer.enabled:   # disabled tracer: no span-arg dict,
            return self._run_stage(node, idxs, ctx)   # no f-string name
        span_args = {"node": node.name, "cluster_node": node.node,
                     "samples": len(idxs),
                     "idxs": [int(i) for i in idxs],
                     "round": round_, "fused": fused, "stream": stream}
        with self.tracer.span(f"stage.{node.name}", cat="graph",
                              args=span_args):
            self._run_stage(node, idxs, ctx)

    def _run_stage(self, node: StageNode, idxs, ctx) -> None:
        budget = (node.max_retries if node.max_retries is not None
                  else self.retry.max_retries)
        for attempt in range(budget + 1):
            try:
                # fault site at stage ENTRY — a retried attempt re-runs the
                # whole stage from the fetch, so retry is idempotent and the
                # outputs of a recovered run are bit-identical to fault-free
                if self.faults is not None:
                    self.faults.check("stage." + node.name)
                io = self._attempt_stage(node, idxs, ctx)
                break
            except TransientError as err:
                if attempt >= budget:
                    self._quarantine(node, idxs, err)
                    return
                self._note_retry(node, attempt, err)
                time.sleep(self.retry.backoff(attempt))
        with self.lock:
            if io.consumed:
                self.dock.mark_consumed(node.name, io.consumed)
            run = self._run
            run.counts[node.name] = (run.counts.get(node.name, 0)
                                     + len(io.consumed))

    def _attempt_stage(self, node: StageNode, idxs, ctx) -> StageIO:
        ins = self._fetch(node, idxs)
        io = StageIO(node, idxs, ins, self)
        out = node.fn(ctx, io)
        if out:
            for fld, rows in out.items():
                self.put(node, fld, io.idxs, rows)
        return io

    def _note_retry(self, node: StageNode, attempt: int, err) -> None:
        self.metrics.inc("graph.retry")
        with self.lock:
            run = getattr(self, "_run", None)
            if run is not None:
                run.retries[node.name] = run.retries.get(node.name, 0) + 1
        if self.tracer.enabled:
            self.tracer.instant("graph.retry", cat="graph",
                                args={"node": node.name, "attempt": attempt,
                                      "error": str(err)})

    def _quarantine(self, node: StageNode, idxs, err) -> None:
        """Retry budget exhausted: drop this dispatch's samples instead of
        poisoning the batch.  The idxs are marked consumed for the failing
        node (so the run quiesces) and recorded on the GraphRun; downstream
        barriers shrink by the quarantined count (``_effective``), so
        surviving samples still flow end to end."""
        dropped = [int(i) for i in idxs]
        with self.lock:
            self.dock.mark_consumed(node.name, idxs)
            run = self._run
            run.quarantined.setdefault(node.name, []).extend(dropped)
            run.quarantined_idxs.update(dropped)
            # NOT added to run.counts: ``_effective`` already shrinks every
            # node's target by the quarantined idxs, and counting them as
            # consumed too would double-subtract — the failing node would
            # stop before processing the samples that were still healthy
        self.metrics.inc("graph.quarantined", len(dropped))
        if self.tracer.enabled:
            self.tracer.instant("graph.quarantine", cat="graph",
                                args={"node": node.name, "idxs": dropped,
                                      "error": str(err)})

    def _effective(self, expected: int | None) -> int | None:
        """Barrier target net of quarantined samples — a dropped sample can
        never arrive, so downstream barriers must not wait for it."""
        if expected is None:
            return None
        return expected - len(self._run.quarantined_idxs)

    def _streaming(self, ctx, graph: RLGraph) -> bool:
        actor = getattr(ctx, "actor", None)
        return (self.rl.stage_fusion
                and actor is not None
                and getattr(actor, "engine_kind", "sync") == "serving"
                and any(n.stream for n in graph.nodes))

    def _poll_stream(self, graph, ctx, expected, seen) -> bool:
        """Dispatch stream nodes on whatever samples became ready while a
        generation-layout stage is draining.  Returns True on progress.
        Stream work dispatched here overlaps the generation stage, so it is
        NOT added to the stage timing buckets."""
        progressed = False
        for node in graph.nodes:
            if not node.stream or node.layout is not None:
                continue
            eff = self._effective(expected)
            if (eff is not None
                    and self._run.counts.get(node.name, 0) >= eff):
                continue
            if not self._peek(node, ctx):
                continue
            idxs = self._available(node, ctx)   # the real, counted request
            key = (node.name, frozenset(idxs))
            if not idxs or key in seen:
                continue
            seen.add(key)
            self._run.trace.append((node.name, tuple(idxs)))
            self._dispatch(node, idxs, ctx, round_=self._run.rounds,
                           fused=True, stream=True)
            progressed = True
        return progressed

    # -- main loop ----------------------------------------------------------
    def run(self, graph: RLGraph, ctx, *, expected: int | None = None
            ) -> GraphRun:
        """Execute ``graph`` until quiescent.

        ``expected``: samples each stage must consume this iteration (barrier
        semantics for non-stream nodes); None makes every node greedy — it
        fires on whatever is ready, but a greedy NON-stream node dispatches
        at most once per run (one quantum per iteration: partial rollout's
        generation node must not re-run on the samples it left unfinished).
        """
        from concurrent.futures import ThreadPoolExecutor

        with self.lock:
            missing = [s for s in graph.states()
                       if s not in self.dock.controllers]
        if missing:
            raise ValueError(f"dock has no controllers for graph states "
                             f"{missing} — build the dock from graph.states()")
        self._run = run = GraphRun()
        run.counts = {n.name: 0 for n in graph.nodes}
        producers = {f: n.name for n in graph.nodes for f in n.outputs}
        self._layout = "update"
        self._stash = None
        seen: set = set()
        dispatched: set = set()       # nodes that ran at least once this run
        try:
            while True:
                runnable = []
                eff = self._effective(expected)
                for node in graph.nodes:
                    if eff is not None and run.counts[node.name] >= eff:
                        continue
                    if (expected is None and not node.stream
                            and node.name in dispatched):
                        continue      # greedy quantum: once per run
                    idxs = self._available(node, ctx)
                    if not idxs:
                        continue
                    key = (node.name, frozenset(idxs))
                    if key in seen:
                        continue      # no progress since last identical try
                    if (eff is not None and not node.stream
                            and run.counts[node.name] + len(idxs) < eff):
                        continue      # barrier: wait for the full batch
                    runnable.append((node, idxs))
                if not runnable:
                    break
                # producer deferral: a node whose input-producer is also
                # runnable this round would fire on a partial view of the
                # producer's output (greedy non-stream nodes fire only once
                # per run, so samples the producer emits later would strand
                # until next iteration — and WHICH samples would depend on
                # streaming poll timing).  Defer the consumer; it fires next
                # round once the producer quiesces.  A topologically minimal
                # runnable node is never deferred, so progress is guaranteed;
                # barrier (expected) rounds are unaffected — a consumer only
                # becomes runnable there after its producer fully ran.
                ready_names = {n.name for n, _ in runnable}
                runnable = [(n, i) for n, i in runnable
                            if not any(producers.get(f) in ready_names
                                       and producers[f] != n.name
                                       for f in n.inputs)]
                run.rounds += 1
                # nodes that agree on a layout dispatch together; the first
                # declared layout requirement picks the round's layout
                want = next((n.layout for n, _ in runnable if n.layout), None)
                batch = ([(n, i) for n, i in runnable
                          if n.layout in (None, want)]
                         if want else runnable)
                if want is not None:
                    self._ensure_layout(ctx, want)
                for node, idxs in batch:
                    seen.add((node.name, frozenset(idxs)))
                    dispatched.add(node.name)
                    run.trace.append((node.name, tuple(idxs)))
                # stage timing is the round's WALL time (fused stages
                # overlap, so their round costs max, not sum — that is the
                # Table 2 speedup Eq. 5 throughput should see), attributed
                # to the round's leading timing bucket
                t0 = time.perf_counter()
                fused = len(batch) > 1
                if (want == "generation" and self._streaming(ctx, graph)):
                    # generation drains in a worker thread; the scheduler
                    # thread polls the metadata plane and starts stream
                    # nodes at sample granularity as on_finish puts land
                    with ThreadPoolExecutor(max_workers=len(batch)) as ex:
                        futs = [ex.submit(self._dispatch, n, i, ctx,
                                          round_=run.rounds, fused=True)
                                for n, i in batch]
                        while not all(f.done() for f in futs):
                            if not self._poll_stream(graph, ctx, expected,
                                                     seen):
                                time.sleep(0.001)
                        for f in futs:
                            f.result()
                elif fused and self.rl.stage_fusion:
                    # stage fusion as a scheduling property: independent
                    # ready nodes run concurrently (paper Table 2)
                    with ThreadPoolExecutor(max_workers=len(batch)) as ex:
                        futs = [ex.submit(self._dispatch, n, i, ctx,
                                          round_=run.rounds, fused=True)
                                for n, i in batch]
                        for f in futs:
                            f.result()
                else:
                    for node, idxs in batch:
                        self._dispatch(node, idxs, ctx, round_=run.rounds,
                                       fused=fused)
                run.stage_times[batch[0][0].timing] += (
                    time.perf_counter() - t0)
        finally:
            # the run always hands the update-layout weights back
            self._ensure_layout(ctx, "update")
        return run


# ---------------------------------------------------------------------------
# group gating helper shared by GRPO-family graphs
# ---------------------------------------------------------------------------

def complete_groups(idxs, group_size: int) -> list:
    """Keep only samples whose FULL group (idx // group_size) is present —
    the readiness gate that lets partial rollout update on complete GRPO
    groups while the rest wait in the warehouses."""
    by_group: dict[int, list] = {}
    for i in idxs:
        by_group.setdefault(int(i) // group_size, []).append(int(i))
    out: list[int] = []
    for gid in sorted(by_group):
        members = by_group[gid]
        if len(members) == group_size:
            out.extend(sorted(members))
    return out


def derive_nodes(base: RLGraph, overrides: dict) -> list:
    """Copy a graph's nodes with per-node field overrides — algorithm
    variants re-declare only what differs instead of duplicating the whole
    topology (PPO and partial rollout are edits of the GRPO graph)."""
    unknown = set(overrides) - {n.name for n in base.nodes}
    if unknown:
        raise ValueError(f"derive_nodes: {sorted(unknown)} not in graph "
                         f"{base.name!r}")
    return [dataclasses.replace(n, **overrides.get(n.name, {}))
            for n in base.nodes]
