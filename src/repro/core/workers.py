"""RL worker states over the model zoo.

Workers mirror the paper's graph (Fig. 1): the ACTOR switches between
generation / inference / update states; REFERENCE and REWARD are
inference-only.  Each worker state is bound to a cluster node (for the
transfer-dock ledger) and exchanges samples exclusively through the dock.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core.grpo import token_logprobs
from repro.core.rollout import RolloutEngine
from repro.models.model import build_model


class ActorWorker:
    """Owns the policy weights; generation/inference/update states."""

    def __init__(self, cfg: ModelConfig, rl: RLConfig, *, eos_id: int,
                 pad_id: int, node: int = 0, engine: str | None = None,
                 tracer=None, faults=None):
        self.cfg = cfg
        self.rl = rl
        self.node = node
        self.model = build_model(cfg)
        self.engine_kind = engine or getattr(rl, "rollout_engine", "sync")
        if self.engine_kind == "serving":
            from repro.serve.engine import ServingEngine

            self.engine = ServingEngine(
                cfg, max_new=rl.max_response_len, eos_id=eos_id,
                pad_id=pad_id, temperature=rl.temperature,
                greedy=getattr(rl, "greedy", False),
                top_p=getattr(rl, "serve_top_p", 1.0),
                top_k=getattr(rl, "serve_top_k", 0),
                seed=getattr(rl, "serve_sampling_seed", 0),
                max_slots=rl.serve_max_slots,
                block_size=rl.serve_block_size,
                prefix_cache=getattr(rl, "serve_prefix_cache", True),
                prefill_chunk=getattr(rl, "serve_prefill_chunk", 0) or None,
                host_tier_blocks=getattr(rl, "serve_host_tier_blocks", 0),
                tracer=tracer, faults=faults)
        elif self.engine_kind == "sync":
            # same truncation knobs: sampled serving ≡ sampled sync is a
            # bitwise contract (tests/test_sampled_serving.py), so the two
            # engines must share every sampling parameter
            self.engine = RolloutEngine(
                cfg, max_new=rl.max_response_len, eos_id=eos_id,
                pad_id=pad_id, temperature=rl.temperature,
                greedy=getattr(rl, "greedy", False),
                top_p=getattr(rl, "serve_top_p", 1.0),
                top_k=getattr(rl, "serve_top_k", 0))
        else:
            raise ValueError(f"unknown rollout engine {self.engine_kind!r}; "
                             f"expected 'sync' or 'serving'")
        self._infer = jax.jit(self._infer_impl)

    def _infer_impl(self, params, batch):
        logits, _ = self.model.forward(params, self.cfg, batch)
        return token_logprobs(logits, batch["tokens"])

    # generation state --------------------------------------------------------
    def generate(self, gen_params, prompts: np.ndarray, key, extras=None,
                 on_finish=None):
        """on_finish(i, tokens_row, mask_row, length) streams each finished
        sample (serving engine only; the synchronized engine has no
        per-sample completion events — rows arrive at the batch barrier)."""
        if self.engine_kind == "serving":
            return self.engine.generate(gen_params, prompts, key, extras,
                                        on_finish=on_finish)
        return self.engine.generate(gen_params, prompts, key, extras)

    # generation state, budgeted (partial rollout) ----------------------------
    # Resume/stream logic lives in the serving engine, not the trainer: a
    # request is submitted (possibly mid-sequence) with a per-request token
    # budget, and run_to_budget hands unfinished ones back resumable.  The
    # engine's prefix cache makes a same-weights resume re-prefill nearly
    # free (suspended blocks stay indexed until reclaimed).
    def submit(self, prompt, *, max_new=None, budget=None, generated=None,
               seed=None, priority=0):
        self._require_serving("submit")
        return self.engine.submit(prompt, max_new=max_new, budget=budget,
                                  generated=generated, seed=seed,
                                  priority=priority)

    def run_to_budget(self, gen_params, on_finish=None):
        self._require_serving("run_to_budget")
        return self.engine.run_to_budget(gen_params, on_finish=on_finish)

    def _require_serving(self, what: str) -> None:
        if self.engine_kind != "serving":
            raise RuntimeError(
                f"{what} needs the serving engine (budgeted/mid-sequence "
                f"requests); this actor runs {self.engine_kind!r}")

    # inference state ---------------------------------------------------------
    def old_logprobs(self, params, tokens: np.ndarray, extras=None):
        batch = {"tokens": jnp.asarray(tokens)}
        if extras:
            batch.update(extras)
        return np.asarray(self._infer(params, batch), np.float32)


class ReferenceWorker:
    def __init__(self, cfg: ModelConfig, ref_params, node: int = 1):
        self.cfg = cfg
        self.node = node
        self.params = ref_params
        self.model = build_model(cfg)
        self._infer = jax.jit(self._infer_impl)

    def _infer_impl(self, params, batch):
        logits, _ = self.model.forward(params, self.cfg, batch)
        return token_logprobs(logits, batch["tokens"])

    def logprobs(self, tokens: np.ndarray, extras=None):
        batch = {"tokens": jnp.asarray(tokens)}
        if extras:
            batch.update(extras)
        return np.asarray(self._infer(self.params, batch), np.float32)


class RewardWorker:
    """Rule reward (the paper's experiments use rule reward + DeepScaleR)."""

    def __init__(self, dataset, node: int = 2):
        self.dataset = dataset
        self.node = node

    def score(self, metas, tokens: np.ndarray, prompt_len: int) -> np.ndarray:
        responses = tokens[:, prompt_len:]
        return self.dataset.score(metas, responses)
