"""GRPO / DAPO losses and the actor update step.

The update step lowered by the dry-run is exactly this: a GRPO policy-gradient
step over (prompt+response) sequences with group-relative advantages, PPO-style
clipping (decoupled upper clip for DAPO) and a k3 KL penalty to the reference
policy — the same loss MindSpeed RL trains Qwen2.5/Qwen3/DeepSeek with.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RLConfig
from repro.models.model import build_model
from repro.optim import adamw_update


def token_logprobs(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """logits: (B, S, V) where logits[:, t] predicts tokens[:, t+1].
    Returns logp of the realized next tokens, shape (B, S-1), fp32.

    Upcasts HERE (not in the model forward) so the backward cotangents
    through the transformer stay in the compute dtype."""
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)                   # (B, S-1)
    tgt = jnp.take_along_axis(lg, tokens[:, 1:, None], axis=-1)[..., 0]
    return tgt - lse


def group_advantages(rewards: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """rewards: (G, N) — N responses per prompt.  Group-relative z-score."""
    mean = jnp.mean(rewards, axis=1, keepdims=True)
    std = jnp.std(rewards, axis=1, keepdims=True)
    return (rewards - mean) / (std + eps)


def grpo_loss(logp, old_logp, ref_logp, advantages, mask, rl: RLConfig):
    """All per-token tensors are (B, T); advantages (B,); mask (B, T) float.

    Returns (loss, metrics).  DAPO == decoupled clip (clip_eps_high) + no KL.
    """
    adv = advantages[:, None]
    ratio = jnp.exp(logp - old_logp)
    hi = rl.clip_eps_high if rl.algorithm == "dapo" else rl.clip_eps
    s1 = ratio * adv
    s2 = jnp.clip(ratio, 1.0 - rl.clip_eps, 1.0 + hi) * adv
    pg = -jnp.minimum(s1, s2)
    # k3 KL estimator (Schulman): unbiased, positive
    dr = ref_logp - logp
    kl = jnp.exp(dr) - dr - 1.0
    kl_coef = 0.0 if rl.algorithm == "dapo" else rl.kl_coef
    per_tok = pg + kl_coef * kl
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(per_tok * mask) / denom
    metrics = {
        "pg_loss": jnp.sum(pg * mask) / denom,
        "kl": jnp.sum(kl * mask) / denom,
        "ratio_mean": jnp.sum(ratio * mask) / denom,
        "clip_frac": jnp.sum(((ratio < 1 - rl.clip_eps) |
                              (ratio > 1 + hi)) * mask) / denom,
    }
    return loss, metrics


def make_train_step(cfg: ModelConfig, rl: RLConfig, lr_schedule=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch: tokens (B,S) int32, response_mask (B,S) f32 (1 on response tokens,
    positions aligned with ``tokens``), advantages (B,), old_logp (B,S-1),
    ref_logp (B,S-1) — plus family extras (frames / vision_embeds).
    """
    model = build_model(cfg)

    def loss_fn(params, batch):
        logits, aux = model.forward(params, cfg, batch)
        logp = token_logprobs(logits, batch["tokens"])            # (B,S-1)
        mask = batch["response_mask"][:, 1:].astype(jnp.float32)
        loss, metrics = grpo_loss(
            logp, batch["old_logp"], batch["ref_logp"],
            batch["advantages"], mask, rl)
        if cfg.is_moe:
            loss = loss + cfg.router_aux_coef * aux
            metrics["moe_aux"] = aux
        if rl.entropy_coef:
            # masked mean token entropy (cheap proxy via sampled logp),
            # SUBTRACTED as a bonus so the objective actually explores
            neg_logp = -jnp.sum(logp * mask) / jnp.maximum(
                jnp.sum(mask), 1.0)
            loss = loss - rl.entropy_coef * neg_logp
            metrics["neg_logp"] = neg_logp
        return loss, metrics

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        lr = (lr_schedule(opt_state.step) if lr_schedule is not None
              else rl.lr)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr, betas=rl.betas,
            weight_decay=rl.weight_decay, grad_clip=rl.grad_clip)
        metrics = dict(metrics, loss=loss,
                       grad_step=opt_state.step.astype(jnp.float32))
        return params, opt_state, metrics

    return train_step
