"""PPO trainer — actor-critic RLHF declared over the same dataflow graph.

Differences from GRPO (`trainer.py`) are pure graph edits: the inference
node also emits critic values, and the advantage node is token-level GAE
over KL-shaped rewards (plus the PF-PPO rank filtration) instead of group
z-scores.  The executor, dock and resharder are untouched — the dataflow
layer is algorithm-agnostic, which is the point of the paper's
architecture (Fig. 6): a new algorithm is a new ``RLGraph``, not a new
trainer loop.  All sample movement routes through the dock's metadata
plane (``request_metadata``/``mark_consumed``), so the dispatch ledger
sees PPO traffic exactly like GRPO traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core import ppo
from repro.core.graph import RLGraph, derive_nodes
from repro.core.resharding import Resharder
from repro.core.trainer import GRPOTrainer, build_grpo_graph
from repro.optim import adamw_init


def build_ppo_graph(actor_node: int = 0, ref_node: int = 1,
                    reward_node: int = 2) -> RLGraph:
    """PPO as a graph EDIT of GRPO: the inference node also emits critic
    values, the advantage node is GAE shaping, the update is the PPO step —
    generation/ref/reward and the topology are inherited."""
    T = PPOTrainer
    base = build_grpo_graph(actor_node, ref_node, reward_node)
    return RLGraph("ppo", derive_nodes(base, {
        "actor_inference": dict(outputs=("old_logp", "values"),
                                fn=T._stage_infer_values),
        "advantages": dict(node=actor_node,
                           inputs=("response_mask", "old_logp", "ref_logp",
                                   "values", "rewards"),
                           outputs=("advantages_tok", "returns",
                                    "values_pad"),
                           fn=T._stage_gae),
        "actor_update": dict(inputs=("tokens", "response_mask", "old_logp",
                                     "values_pad", "advantages_tok",
                                     "returns"),
                             fn=T._stage_ppo_update),
    }))


class PPOTrainer(GRPOTrainer):
    def __init__(self, cfg: ModelConfig, rl: RLConfig, dataset, *,
                 pf_filter: bool = False, **kw):
        rl = rl.replace(algorithm="ppo")
        self.pf = pf_filter
        super().__init__(cfg, rl, dataset, **kw)
        key = jax.random.PRNGKey(kw.get("seed", 0) + 17)
        self.params = ppo.add_value_head(self.params, cfg, key)
        self.opt_state = adamw_init(self.params)
        self.train_step = jax.jit(ppo.make_train_step(cfg, rl),
                                  donate_argnums=(0, 1))
        self._values = jax.jit(self._values_impl)
        # the resharder must carry the value head too
        from repro.sharding import param_specs
        tspecs = param_specs(cfg, self.params, self.mesh, stage="train")
        gspecs = param_specs(cfg, self.params, self.mesh, stage="gen",
                             gen_mode="tp")
        self.resharder = Resharder(self.mesh, tspecs, gspecs,
                                   use_swap=rl.use_allgather_swap)

    def _build_graph(self) -> RLGraph:
        return build_ppo_graph(self.actor.node, self.ref.node,
                               self.reward.node)

    def _values_impl(self, params, batch):
        return ppo.value_forward(params, self.cfg, batch)

    # -- PPO samples one response per prompt (no group repeat) ------------
    def _enqueue(self, global_batch: int) -> int:
        G = global_batch
        prompts, plens, metas = self.dataset.sample(G)
        self._plen = prompts.shape[1]
        self._metas = dict(enumerate(metas))
        self.dock.put("prompt", list(range(G)), prompts,
                      src_node=self.actor.node)
        return G

    # -- stage callables ---------------------------------------------------
    def _stage_infer_values(self, io):
        toks = io.ins["tokens"]
        old_logp = self.actor.old_logprobs(self.params, toks)
        values = np.asarray(
            self._values(self.params, {"tokens": jnp.asarray(toks)}),
            np.float32)
        return {"old_logp": old_logp, "values": values}

    def _stage_gae(self, io):
        """Token-level shaped rewards (-kl per token + terminal task reward)
        -> GAE advantages/returns, optionally PF-PPO filtered."""
        rl = self.rl
        G = len(io.idxs)
        mask = io.ins["response_mask"]
        old_logp = io.ins["old_logp"]
        ref_logp = io.ins["ref_logp"]
        values = io.ins["values"]
        rewards = io.ins["rewards"][:, 0]
        self._it["rewards_arr"] = rewards

        kl = old_logp - ref_logp                           # (G, S-1)
        tok_rewards = -rl.kl_coef * kl
        m = mask[:, 1:]
        last = np.maximum(m.cumsum(1).argmax(1), 0)
        tok_rewards[np.arange(G), last] += rewards
        adv, ret = ppo.gae(jnp.asarray(tok_rewards),
                           jnp.asarray(values[:, 1:] * m),
                           jnp.asarray(m), rl.gamma, rl.gae_lambda)
        adv = np.asarray(adv)
        if self.pf:
            w = np.asarray(ppo.pf_filter(jnp.asarray(rewards)))
            adv = adv * w[:, None]
        pad = lambda a: np.concatenate(                    # noqa: E731
            [np.zeros((G, 1), np.float32), a], axis=1)
        self._it["kl_stat"] = float(np.mean(np.abs(kl * m)))
        return {"advantages_tok": pad(adv),
                "returns": pad(np.asarray(ret)),
                "values_pad": pad(np.asarray(values[:, 1:]))}

    def _stage_ppo_update(self, io):
        ins = io.ins
        tb = {
            "tokens": jnp.asarray(ins["tokens"]),
            "response_mask": jnp.asarray(ins["response_mask"]),
            "old_logp": jnp.asarray(ins["old_logp"]),
            "values": jnp.asarray(ins["values_pad"]),
            "old_values": jnp.asarray(ins["values_pad"]),
            "advantages_tok": jnp.asarray(ins["advantages_tok"]),
            "returns": jnp.asarray(ins["returns"]),
        }
        self.params, self.opt_state, metrics = self.train_step(
            self.params, self.opt_state, tb)
        self._it["losses"].append(float(metrics["loss"]))
        return None
