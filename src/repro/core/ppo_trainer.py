"""PPO trainer — actor-critic RLHF over the same MindSpeed-RL dataflow.

Differences from GRPO (`trainer.py`): a value head on the actor trunk
(critic), token-level KL-shaped rewards, GAE advantages, and the PPO clipped
value loss.  PF-PPO (policy filtration) reweights rollouts by reward rank.
The sample flow still moves through the transfer dock and the weights through
the allgather-swap resharder — the dataflow layer is algorithm-agnostic,
which is the point of the paper's architecture (Fig. 6).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RLConfig
from repro.core import grpo, ppo
from repro.core.resharding import Resharder
from repro.core.trainer import GRPOTrainer, IterationStats
from repro.models.model import build_model
from repro.optim import adamw_init


class PPOTrainer(GRPOTrainer):
    def __init__(self, cfg: ModelConfig, rl: RLConfig, dataset, *,
                 pf_filter: bool = False, **kw):
        rl = rl.replace(algorithm="ppo")
        super().__init__(cfg, rl, dataset, **kw)
        self.pf = pf_filter
        key = jax.random.PRNGKey(kw.get("seed", 0) + 17)
        self.params = ppo.add_value_head(self.params, cfg, key)
        self.opt_state = adamw_init(self.params)
        self.train_step = jax.jit(ppo.make_train_step(cfg, rl),
                                  donate_argnums=(0, 1))
        self._values = jax.jit(self._values_impl)
        # the resharder must carry the value head too
        from repro.sharding import param_specs
        tspecs = param_specs(cfg, self.params, self.mesh, stage="train")
        gspecs = param_specs(cfg, self.params, self.mesh, stage="gen",
                             gen_mode="tp")
        self.resharder = Resharder(self.mesh, tspecs, gspecs,
                                   use_swap=rl.use_allgather_swap)

    def _values_impl(self, params, batch):
        return ppo.value_forward(params, self.cfg, batch)

    def iteration(self, global_batch: int) -> IterationStats:
        cfg, rl = self.cfg, self.rl
        G = global_batch
        self.dock.clear()
        prompts, plens, metas = self.dataset.sample(G)
        pl = prompts.shape[1]
        idxs = list(range(G))
        self.dock.put("prompt", idxs, prompts, src_node=0)

        gen_params, stash, reshard_led = self.resharder.to_generation(
            self.params)
        del self.params

        t0 = time.perf_counter()
        ready = self.dock.request_metadata("actor_generation", ["prompt"])
        pb = self.dock.get("actor_generation", "prompt", ready, dst_node=0)
        self.key, k = jax.random.split(self.key)
        roll = self.actor.generate(gen_params, pb, k)
        self.dock.put("tokens", ready, roll.tokens, src_node=0)
        self.dock.put("response_mask", ready, roll.response_mask, src_node=0)
        self.dock.mark_consumed("actor_generation", ready)
        gen_time = time.perf_counter() - t0
        del gen_params
        self.params, reshard_led = self.resharder.to_update(stash, reshard_led)

        # inference stage: old logp, values, ref logp, rewards
        t0 = time.perf_counter()
        toks = self.dock.get("actor_inference", "tokens", idxs, dst_node=0)
        mask = self.dock.get("actor_inference", "response_mask", idxs, 0)
        batch = {"tokens": jnp.asarray(toks)}
        old_logp = self.actor.old_logprobs(self.params, toks)
        values = np.asarray(self._values(self.params, batch), np.float32)
        ref_logp = self.ref.logprobs(toks)
        rewards = self.reward.score(metas, toks, pl)

        # token-level shaped rewards: -kl per token + terminal task reward
        kl = old_logp - ref_logp                           # (G, S-1)
        tok_rewards = -rl.kl_coef * kl
        m = mask[:, 1:]
        last = np.maximum(m.cumsum(1).argmax(1), 0)
        tok_rewards[np.arange(G), last] += rewards
        adv, ret = ppo.gae(jnp.asarray(tok_rewards),
                           jnp.asarray(values[:, 1:] * m),
                           jnp.asarray(m), rl.gamma, rl.gae_lambda)
        adv = np.asarray(adv)
        if self.pf:
            w = np.asarray(ppo.pf_filter(jnp.asarray(rewards)))
            adv = adv * w[:, None]
        pad = lambda a: np.concatenate(
            [np.zeros((G, 1), np.float32), a], axis=1)
        infer_time = time.perf_counter() - t0

        t0 = time.perf_counter()
        tb = {
            "tokens": jnp.asarray(toks),
            "response_mask": jnp.asarray(mask),
            "old_logp": jnp.asarray(old_logp),
            "values": jnp.asarray(pad(np.asarray(values[:, 1:]))),
            "old_values": jnp.asarray(pad(np.asarray(values[:, 1:]))),
            "advantages_tok": jnp.asarray(pad(adv)),
            "returns": jnp.asarray(pad(np.asarray(ret))),
        }
        self.params, self.opt_state, metrics = self.train_step(
            self.params, self.opt_state, tb)
        update_time = time.perf_counter() - t0

        return IterationStats(
            reward_mean=float(np.mean(rewards)),
            reward_std=float(np.std(rewards)),
            loss=float(metrics["loss"]),
            kl=float(np.mean(np.abs(kl * m))),
            gen_time=gen_time, infer_time=infer_time, update_time=update_time,
            reshard=reshard_led.snapshot(),
            dispatch=self.dock.ledger.snapshot(),
        )
