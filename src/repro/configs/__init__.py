"""Config registry.

``get_config("mixtral-8x7b")`` returns the full assigned ModelConfig;
``get_smoke_config(...)`` returns the reduced same-family variant used by the
CPU smoke tests (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    TPU_V5E,
    HardwareConfig,
    ModelConfig,
    RLConfig,
    ShapeConfig,
)

# arch id -> module name (dashes are not importable)
_ARCH_MODULES = {
    "mamba2-1.3b": "mamba2_1_3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "starcoder2-7b": "starcoder2_7b",
    "yi-6b": "yi_6b",
    "stablelm-3b": "stablelm_3b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen1.5-110b": "qwen1_5_110b",
    "whisper-large-v3": "whisper_large_v3",
    # the paper's own evaluation models
    "qwen2.5-7b": "qwen2_5_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-moe-30b": "qwen3_moe_30b",
}

ASSIGNED_ARCHS = list(_ARCH_MODULES)[:10]
PAPER_ARCHS = list(_ARCH_MODULES)[10:]
ALL_ARCHS = list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ALL_ARCHS}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke()
