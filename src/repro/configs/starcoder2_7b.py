"""starcoder2-7b — dense GQA + RoPE + sliding window [arXiv:2402.19173].

32L d_model=4608 36H (kv=4, head_dim=128) d_ff=18432 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4608,
    vocab_size=49_152,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    rope_theta=1e5,
    sliding_window=4096,
    qkv_bias=True,
    norm_type="layernorm",
    mlp_type="gelu",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="starcoder2-smoke",
        num_layers=2,
        d_model=288,
        vocab_size=512,
        num_heads=9,
        num_kv_heads=1,
        head_dim=32,
        d_ff=768,
        sliding_window=64,
    )
