"""stablelm-3b — dense, MHA (kv=32) [hf:stabilityai/stablelm-2-1_6b family].

32L d_model=2560 32H (kv=32, head_dim=80) d_ff=6912 vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    arch_type="dense",
    num_layers=32,
    d_model=2560,
    vocab_size=50_304,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    rope_theta=10_000.0,
    qkv_bias=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="stablelm-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=32,
        d_ff=512,
    )
