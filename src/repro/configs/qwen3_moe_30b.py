"""qwen3-moe-30b (Qwen3-30B-A3B) — the paper's own MoE evaluation model
[arXiv:2505.09388].

48L d_model=2048 32H (kv=4, head_dim=128) 128 experts top-8, expert d_ff=768,
vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    vocab_size=151_936,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    num_experts=128,
    experts_per_token=8,
    rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-moe-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=128,
        num_experts=4,
        experts_per_token=2,
    )
