"""mixtral-8x7b — 8 experts top-2 MoE, GQA, sliding-window attn [arXiv:2401.04088].

32L d_model=4096 32H (kv=8, head_dim=128) expert d_ff=14336 vocab=32000, SWA 4096.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    vocab_size=32_000,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    num_experts=8,
    experts_per_token=2,
    rope_theta=1e6,
    sliding_window=4096,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="mixtral-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        num_experts=4,
        experts_per_token=2,
        sliding_window=64,
    )
