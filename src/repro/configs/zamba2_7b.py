"""zamba2-7b — hybrid: Mamba2 backbone + shared attention block [arXiv:2411.15242].

81L d_model=3584 32H (kv=32, head_dim=112) d_ff=14336 vocab=32000, ssm_state=64.
The single shared transformer block (MHA + MLP) is applied every
``hybrid_attn_period`` mamba layers, reusing ONE weight set (weight aliasing —
the resharding flow must gather it exactly once).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    num_layers=81,
    d_model=3584,
    vocab_size=32_000,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=2,
    hybrid_attn_period=6,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=32,
        d_ff=512,
        ssm_state=32,
        ssm_chunk=32,
        hybrid_attn_period=2,
    )
