"""Config system for MindSpeed-RL-on-JAX.

Three config families:
  * ModelConfig   — architecture hyperparameters (one per assigned arch).
  * ShapeConfig   — the four assigned input shapes (train/prefill/decode/long).
  * RLConfig      — GRPO/PPO algorithm + dataflow (transfer dock, resharding).

Everything is a frozen dataclass so configs hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # --- attention (0 heads => attention-free family) ---
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 => full causal attention
    mrope_sections: Tuple[int, ...] = ()   # M-RoPE (qwen2-vl): head_dim split t/h/w
    # --- mlp ---
    d_ff: int = 0
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "dispatch"       # dispatch (capacity einsum) | gmm (dropless)
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 128             # SSD chunk length for training/prefill
    # --- hybrid (zamba2): shared attention block applied every k layers ---
    hybrid_attn_period: int = 0      # 0 => not hybrid
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # fixed #frame embeddings from the stub frontend
    # --- vlm ---
    vision_tokens: int = 0           # #patch embeddings provided by the stub frontend
    # --- numerics / training ---
    dtype: str = "bfloat16"
    remat: bool = True
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    mlp_type: str = "swiglu"         # swiglu | gelu
    tie_embeddings: bool = False

    @property
    def is_attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:        # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape.  kind selects which program is lowered."""
    name: str
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int

    def replace(self, **kw) -> "ShapeConfig":
        return dataclasses.replace(self, **kw)


INPUT_SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    "train",   4_096,   256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  ShapeConfig("decode_32k",  "decode",  32_768,  128),
    "long_500k":   ShapeConfig("long_500k",   "decode",  524_288, 1),
}


@dataclass(frozen=True)
class RLConfig:
    """GRPO/PPO algorithm + MindSpeed-RL dataflow knobs."""
    algorithm: str = "grpo"          # grpo | ppo | dapo
    num_generations: int = 8         # N responses per prompt (GRPO group size)
    clip_eps: float = 0.2
    clip_eps_high: float = 0.28      # DAPO decoupled upper clip
    kl_coef: float = 0.001
    entropy_coef: float = 0.0
    gamma: float = 1.0
    gae_lambda: float = 0.95
    temperature: float = 1.0
    greedy: bool = False             # argmax decoding (bit-reproducible runs)
    max_prompt_len: int = 64
    max_response_len: int = 64
    # --- optimizer ---
    lr: float = 1e-5
    betas: Tuple[float, float] = (0.9, 0.95)
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    zero_optimizer: bool = False     # ZeRO-shard optimizer moments over data axis
    # --- generation engine ---
    rollout_engine: str = "sync"     # sync (batch RolloutEngine) | serving
    serve_max_slots: int = 8         # continuous-batching slot count
    serve_block_size: int = 16       # paged KV-cache block size (tokens)
    serve_prefix_cache: bool = True  # ref-counted prompt-head block sharing
    serve_prefill_chunk: int = 0     # chunked prefill: max prefill tokens
    #                                  per engine step (0 = whole-prompt
    #                                  admission prefill, the classic path)
    serve_host_tier_blocks: int = 0  # host-RAM KV tier capacity in blocks
    #                                  (0 = off): reclaimed-but-indexed
    #                                  blocks spill to host and preempted/
    #                                  suspended requests swap their KV
    #                                  back in instead of re-prefilling
    serve_sampling_seed: int = 0     # run key for counter-based per-request
    #                                  sampling streams: request `seed`
    #                                  samples token t with
    #                                  fold_in(fold_in(PRNGKey(this), seed),
    #                                  t) — replayable, schedule-independent
    serve_top_p: float = 1.0         # nucleus sampling mass (1.0 = off);
    #                                  fused into the jitted decode step
    serve_top_k: int = 0             # top-k truncation (0 = off); both
    #                                  knobs apply to sync AND serving
    #                                  engines (the sampled bit-identity
    #                                  contract requires shared parameters)
    # --- dataflow (the paper's contribution) ---
    use_transfer_dock: bool = True   # False => centralized replay buffer baseline
    num_warehouses: int = 4          # S, usually = #nodes
    use_allgather_swap: bool = True  # False => naive resharding baseline
    overlap_h2d: bool = True         # prefetch H2D swap during inference stage
    partial_rollout: bool = False
    stage_fusion: bool = True        # overlap ref-inference with reward scoring
    # --- bandwidth model for dispatch accounting (paper: 300 MB/s inter-server,
    #     50 GB/s H2D/D2H) ---
    internode_bw: float = 300e6
    h2d_bw: float = 50e9
    # --- observability (repro.obs) ---
    trace_path: Optional[str] = None  # write a Chrome-trace/Perfetto JSON
    #                                  here (train.py --trace); None disables
    #                                  tracing (registry metrics stay on)

    def replace(self, **kw) -> "RLConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# TPU v5e hardware constants used by the roofline analysis (targets, since the
# container executes on CPU).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HardwareConfig:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9          # HBM capacity per chip


TPU_V5E = HardwareConfig()
