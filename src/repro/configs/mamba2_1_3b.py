"""mamba2-1.3b — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L d_model=2048, d_ff=0 (the Mamba2 block subsumes the MLP), vocab=50280,
ssm_state=128, expand=2 (d_inner=4096), head_dim=64 -> 64 SSM heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    vocab_size=50280,
    d_ff=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_conv_kernel=4,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="mamba2-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        ssm_state=32,
        ssm_head_dim=64,
        ssm_chunk=32,
    )
