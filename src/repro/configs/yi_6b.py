"""yi-6b — llama-architecture dense GQA [arXiv:2403.04652].

32L d_model=4096 32H (kv=4, head_dim=128) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    vocab_size=64_000,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    rope_theta=5e6,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="yi-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
    )
