"""llama4-maverick-400b-a17b — MoE 128 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family].

48L d_model=5120 40H (kv=8, head_dim=128) expert d_ff=8192 vocab=202048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    vocab_size=202_048,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    num_experts=128,
    experts_per_token=1,
    rope_theta=5e5,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="llama4-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        num_experts=4,
        experts_per_token=1,
    )
