"""qwen2.5-7b — the paper's own dense evaluation model [arXiv:2412.15115].

28L d_model=3584 28H (kv=4, head_dim=128) d_ff=18944 vocab=152064, QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    vocab_size=152_064,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    rope_theta=1e6,
    qkv_bias=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2.5-7b-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
    )
