"""qwen2-vl-72b — VLM backbone with M-RoPE [arXiv:2409.12191].

80L d_model=8192 64H (kv=8, head_dim=128) d_ff=29568 vocab=152064.
Vision frontend is a STUB per the carve-out: ``input_specs`` provides
precomputed patch embeddings (vision_tokens, d_model); the backbone scatters
them over the leading token positions and applies M-RoPE with 3-D position
ids split (t,h,w)=(16,24,24) over the half head-dim.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    num_layers=80,
    d_model=8192,
    vocab_size=152_064,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    rope_theta=1e6,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    vision_tokens=256,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-vl-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        mrope_sections=(4, 6, 6),
        vision_tokens=4,
    )
