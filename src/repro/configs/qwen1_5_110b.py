"""qwen1.5-110b — dense GQA with QKV bias [hf:Qwen/Qwen1.5 family].

80L d_model=8192 64H (kv=8, head_dim=128) d_ff=49152 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    arch_type="dense",
    num_layers=80,
    d_model=8192,
    vocab_size=152_064,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    rope_theta=1e6,
    qkv_bias=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="qwen1.5-smoke",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
    )
