"""whisper-large-v3 — encoder-decoder, conv frontend stubbed [arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model=1280 20H (MHA, head_dim=64)
d_ff=5120 vocab=51866.  The mel-spectrogram + conv feature extractor is a
STUB per the carve-out: ``input_specs`` provides 1500 precomputed frame
embeddings of width d_model.  Decoder uses learned positions (no RoPE in
whisper); we keep rope_theta for the shared layer code but disable rope via
``rope_theta=0``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    num_layers=32,            # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    vocab_size=51_866,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    rope_theta=0.0,           # sinusoidal absolute positions (no RoPE)
    norm_type="layernorm",
    mlp_type="gelu",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-smoke",
        num_layers=2,
        encoder_layers=2,
        encoder_seq=32,
        d_model=256,
        vocab_size=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=32,
        d_ff=512,
    )
