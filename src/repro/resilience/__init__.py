"""repro.resilience — deterministic fault injection + recovery policy.

The failure-handling layer for the dataflow runtime (docs/resilience.md):

  * :class:`FaultPlan` — a replayable, seeded schedule of injected
    failures at named sites (``stage.<node>``, ``dock.put``, ``swap.out``,
    ``swap.in``), threaded into ``GraphExecutor`` / ``TransferDock`` /
    ``SwapEngine`` via their ``faults=`` hooks.
  * :class:`RetryPolicy` / :func:`call_with_retry` — capped deterministic
    backoff for :class:`TransientError` failures (the executor's stage
    retry and dock-put retry paths).

Recovery semantics live with the components: stage retry + sample
quarantine in ``repro.core.graph``, swap-failure degradation in
``repro.serve``, iteration checkpoint/resume in ``repro.checkpoint``.
"""
from repro.resilience.faults import (FatalFault, FaultPlan, FaultSpec,
                                     InjectedFault, TransientError,
                                     TransientFault)
from repro.resilience.retry import RetryPolicy, call_with_retry

__all__ = ["FaultPlan", "FaultSpec", "InjectedFault", "TransientFault",
           "FatalFault", "TransientError", "RetryPolicy", "call_with_retry"]
