"""Deterministic fault injection: a replayable schedule of named failures.

Chaos testing for the dataflow runtime.  A :class:`FaultPlan` is a
schedule — "the 3rd time execution passes fault site ``swap.out``, fail it"
— threaded into the runtime via the ``faults=`` constructor hook on
``GraphExecutor``, ``TransferDock``, and ``SwapEngine``.  Instrumented code
calls ``plan.check(site)`` at each named site; the plan counts occurrences
per site and raises at exactly the scheduled hits.  Because scheduling is
keyed on (site, occurrence-count) rather than wall-clock or process-global
RNG, a plan replays the same failures on every run of a deterministic
workload (DET002: randomized plans use an explicit ``random.Random(seed)``
instance, never the module-level generator).

Fault sites (cataloged in docs/resilience.md; FLT001 enforces the catalog):

  * ``stage.<node>`` — entry of a graph stage dispatch (one name per
    ``StageNode``, e.g. ``stage.actor_generation``).
  * ``dock.put``     — entry of ``TransferDock.put``, before any row lands
    (so a retried put is exactly idempotent).
  * ``swap.out``     — host-tier spill job, inside the swap worker.
  * ``swap.in``      — host-tier swap-in job, inside the swap worker.

Two failure kinds:

  * ``transient`` (:class:`TransientFault`) — the recovery policy's bread
    and butter: retried by ``GraphExecutor`` with capped deterministic
    backoff; inside the swap worker any failure (transient or not)
    permanently degrades the tier (see docs/resilience.md).
  * ``fatal`` (:class:`FatalFault`) — never retried; propagates to the
    driver, which exits with status 3 (``train.py``).  Used by CI to force
    a mid-run abort and prove ``--resume``.

The textual spec format round-trips through :meth:`FaultPlan.parse` /
:meth:`FaultPlan.describe` so any observed failure schedule can be
replayed from a CLI flag::

    --fault-plan 'stage.reward@1,swap.out@2,stage.actor_update@3:fatal'
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass

KINDS = ("transient", "fatal")


class TransientError(RuntimeError):
    """Base class for errors the retry policy may safely re-attempt.

    Raise a subclass from a stage callable to opt a failure into
    ``GraphExecutor``'s retry-with-backoff path; anything else propagates
    immediately."""


class InjectedFault(RuntimeError):
    """An injected failure (never raised by real code paths)."""

    def __init__(self, site: str, hit: int):
        super().__init__(f"injected fault at {site}@{hit}")
        self.site = site
        self.hit = hit


class TransientFault(InjectedFault, TransientError):
    """Injected failure the retry/degradation policy is expected to absorb."""


class FatalFault(InjectedFault):
    """Injected failure that must abort the run (exercises checkpoint/resume)."""


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled failure: the ``hit``-th (1-based) arrival at ``site``."""
    site: str
    hit: int
    kind: str = "transient"

    def __post_init__(self):
        if self.hit < 1:
            raise ValueError(f"hit is 1-based, got {self.hit}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")

    def describe(self) -> str:
        text = f"{self.site}@{self.hit}"
        return text if self.kind == "transient" else f"{text}:{self.kind}"


class FaultPlan:
    """A deterministic, thread-safe schedule of injected failures.

    ``check(site)`` increments the site's arrival counter and raises a
    :class:`TransientFault` / :class:`FatalFault` when the arrival matches
    a scheduled spec.  Counters are per-plan state: ``reset()`` rewinds the
    schedule so the same plan object can replay against a second run.
    """

    def __init__(self, specs: list[FaultSpec] | None = None):
        self._lock = threading.Lock()
        self._sched: dict[str, dict[int, str]] = {}  # guarded-by: _lock
        self._counts: dict[str, int] = {}            # guarded-by: _lock
        self._fired: list[FaultSpec] = []            # guarded-by: _lock
        for spec in specs or []:
            self._sched.setdefault(spec.site, {})[spec.hit] = spec.kind

    # -- construction -------------------------------------------------------
    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Build a plan from ``site@hit[:kind]`` comma-separated specs
        (the ``--fault-plan`` flag format; inverse of :meth:`describe`)."""
        specs = []
        for item in text.split(","):
            item = item.strip()
            if not item:
                continue
            site, _, rest = item.partition("@")
            if not site or not rest:
                raise ValueError(f"bad fault spec {item!r} "
                                 f"(expected site@hit[:kind])")
            hit_s, _, kind = rest.partition(":")
            specs.append(FaultSpec(site, int(hit_s), kind or "transient"))
        return cls(specs)

    @classmethod
    def random_plan(cls, seed: int, sites: list[str], n: int, *,
                    max_hit: int = 16, kind: str = "transient") -> "FaultPlan":
        """Seeded randomized plan for sweep tests: ``n`` faults drawn over
        ``sites`` x ``[1, max_hit]`` from an explicit ``random.Random(seed)``
        instance (no process-global RNG — DET002)."""
        rng = random.Random(seed)
        chosen: set[tuple[str, int]] = set()
        while len(chosen) < n:
            chosen.add((rng.choice(sites), rng.randint(1, max_hit)))
        return cls([FaultSpec(site, hit, kind)
                    for site, hit in sorted(chosen)])

    # -- the injection point ------------------------------------------------
    def check(self, site: str) -> None:
        """Count an arrival at ``site``; raise if this hit is scheduled."""
        with self._lock:
            hit = self._counts.get(site, 0) + 1
            self._counts[site] = hit
            kind = self._sched.get(site, {}).get(hit)
            if kind is None:
                return
            self._fired.append(FaultSpec(site, hit, kind))
        if kind == "fatal":
            raise FatalFault(site, hit)
        raise TransientFault(site, hit)

    # -- introspection / replay ---------------------------------------------
    @property
    def fired(self) -> list[FaultSpec]:
        """Specs that actually triggered so far, in firing order."""
        with self._lock:
            return list(self._fired)

    def counts(self) -> dict[str, int]:
        """Arrivals seen per site (for coverage assertions in sweeps)."""
        with self._lock:
            return dict(self._counts)

    def reset(self) -> None:
        """Rewind arrival counters so the plan replays from the start."""
        with self._lock:
            self._counts.clear()
            self._fired.clear()

    def describe(self) -> str:
        """The plan as a ``--fault-plan`` spec string (parse round-trips)."""
        with self._lock:
            specs = [FaultSpec(site, hit, kind)
                     for site, hits in sorted(self._sched.items())
                     for hit, kind in sorted(hits.items())]
        return ",".join(s.describe() for s in specs)

    def __repr__(self) -> str:
        return f"FaultPlan({self.describe()!r})"
