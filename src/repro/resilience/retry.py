"""Retry-with-backoff policy for transient failures.

The policy is deliberately deterministic: backoff grows geometrically from
``backoff_base_s`` and is capped at ``backoff_cap_s`` — no jitter, so a
replayed :class:`~repro.resilience.faults.FaultPlan` produces the same
retry schedule every run.  Only :class:`TransientError` subclasses are
retried; everything else propagates on first raise.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.resilience.faults import TransientError


@dataclass(frozen=True)
class RetryPolicy:
    """How many times to re-attempt a transient failure, and how long to
    wait between attempts (capped geometric backoff, no jitter)."""
    max_retries: int = 2
    backoff_base_s: float = 0.001
    backoff_cap_s: float = 0.05

    def backoff(self, attempt: int) -> float:
        """Sleep before retry number ``attempt`` (0-based)."""
        return min(self.backoff_base_s * (2 ** attempt), self.backoff_cap_s)


def call_with_retry(fn, policy: RetryPolicy, *, retries: int | None = None,
                    on_retry=None):
    """Call ``fn()`` retrying :class:`TransientError` up to the budget.

    ``retries`` overrides ``policy.max_retries`` (a per-call budget, e.g.
    ``StageNode.max_retries``); ``on_retry(attempt, err)`` is invoked
    before each backoff sleep (telemetry hook).  The final failure
    re-raises the last transient error.
    """
    budget = policy.max_retries if retries is None else retries
    attempt = 0
    while True:
        try:
            return fn()
        except TransientError as err:
            if attempt >= budget:
                raise
            if on_retry is not None:
                on_retry(attempt, err)
            time.sleep(policy.backoff(attempt))
            attempt += 1
