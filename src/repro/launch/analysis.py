"""Roofline analysis from the compiled dry-run artifact.

All quantities from ``compiled.cost_analysis()`` / the post-SPMD HLO are
PER DEVICE (verified empirically: flops of a sharded matmul ≈ global/chips).
Terms (seconds, per chip — TPU v5e targets):

    compute    = HLO_flops_per_device / peak_flops
    memory     = HLO_bytes_per_device / hbm_bw
    collective = modeled_ring_bytes_per_device / ici_bw

collective bytes are parsed from the HLO text: for each
all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute op we take
the result buffer size and model ring traffic over its replica group.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field


from repro.configs.base import TPU_V5E, HardwareConfig, ModelConfig

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_TUPLE_COLL_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _buffer_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _ring_factor(kind: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind == "all-gather":
        return (g - 1) / g            # result is the full gathered buffer
    if kind == "reduce-scatter":
        return float(g - 1)           # result is the small shard
    if kind == "all-to-all":
        return (g - 1) / g
    return 1.0                        # collective-permute


@dataclass
class CollectiveStats:
    ops: list = field(default_factory=list)   # (kind, result_bytes, gsize)

    @property
    def modeled_bytes(self) -> float:
        return sum(b * _ring_factor(k, g) for k, b, g in self.ops)

    @property
    def raw_result_bytes(self) -> float:
        return sum(b for _, b, _ in self.ops)

    def by_kind(self) -> dict:
        out = {}
        for k, b, g in self.ops:
            d = out.setdefault(k, {"count": 0, "modeled_bytes": 0.0})
            d["count"] += 1
            d["modeled_bytes"] += b * _ring_factor(k, g)
        return out


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        buf = None
        kind = None
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            buf = _buffer_bytes(dtype, dims)
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if mt:
                kind = mt.group(2)
                buf = sum(_buffer_bytes(d, s)
                          for d, s in _SHAPE_RE.findall(mt.group(1)))
                if kind == "all-gather" or kind == "all-reduce":
                    buf //= 2  # start-op tuples carry (operand, result)
        if buf is None:
            continue
        gm = _GROUPS_RE.search(line)
        gsize = int(gm.group(2)) if gm else 1
        stats.ops.append((kind, buf, gsize))
    return stats


def model_flops(cfg: ModelConfig, tokens: int) -> float:
    """6·N_active·D — the 'useful' FLOPs for D processed tokens."""
    return 6.0 * active_params(cfg) * tokens


def active_params(cfg: ModelConfig) -> float:
    """Parameter count on the active path (MoE counts top-k experts)."""
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    n = 2 * v * d                      # embed + lm_head
    if cfg.arch_type == "ssm":
        di, g, ds, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
        per = 2 * d * di + 2 * d * g * ds + d * h + di * d
        return n + L * per
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    attn = d * h * hd + 2 * d * kv * hd + h * hd * d
    if cfg.mlp_type == "swiglu":
        mlp = 3 * d * cfg.d_ff
    else:
        mlp = 2 * d * cfg.d_ff
    if cfg.is_moe:
        mlp = mlp * cfg.experts_per_token + d * cfg.num_experts
    if cfg.arch_type == "hybrid":
        di, g, ds, hh = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
        per = 2 * d * di + 2 * d * g * ds + d * hh + di * d
        n_sites = L // cfg.hybrid_attn_period
        # shared block weights are ONE set, but compute runs n_sites times —
        # for the 6·N·D FLOPs estimate we count compute-equivalents.
        return n + L * per + n_sites * (attn + mlp)
    if cfg.arch_type == "audio":
        enc = cfg.encoder_layers * (attn + mlp)
        dec = L * (2 * attn + mlp)     # self + cross attention
        return n + enc + dec
    return n + L * (attn + mlp)


def total_params(cfg: ModelConfig) -> float:
    """All parameters (MoE counts every expert)."""
    if not cfg.is_moe:
        return active_params(cfg)
    d, L = cfg.d_model, cfg.num_layers
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    attn = d * h * hd + 2 * d * kv * hd + h * hd * d
    mlp = 3 * d * cfg.d_ff * cfg.num_experts + d * cfg.num_experts
    return 2 * cfg.vocab_size * d + L * (attn + mlp)


def analytic_bytes(cfg: ModelConfig, kind: str, global_batch: int,
                   seq_len: int, chips: int, capacity: int = 0) -> float:
    """Kernel-ideal per-device HBM bytes (what the TPU Pallas kernels would
    pay, vs. the CPU-path HLO whose chunked-attention loop carries spill to
    HBM).  Coarse napkin model, clearly labeled in the tables."""
    p_total = total_params(cfg)
    p_loc = p_total / chips * 2                       # bf16 weights shard
    b_loc = max(global_batch / chips, 1e-9)
    d = cfg.d_model
    if kind == "train":
        w = 3 * p_loc                                 # fwd + remat + bwd reads
        g = p_loc                                     # grad write (bf16)
        opt = p_total / chips * 4 * 4                 # m,v fp32 read+write
        act = cfg.num_layers * b_loc * seq_len * d * 2 * 6
        logits = 3 * b_loc * seq_len * cfg.vocab_size / max(chips ** 0.5, 1) * 4
        return w + g + opt + act + logits
    if kind == "prefill":
        act = cfg.num_layers * b_loc * seq_len * d * 2 * 3
        return p_loc + act
    # decode: read active weights once + cache once
    act_p = active_params(cfg) / chips * 2
    cache = 0.0
    if not cfg.is_attention_free:
        kvb = (cfg.num_layers * b_loc * (capacity or seq_len)
               * cfg.num_kv_heads * cfg.head_dim * 2 * 2)
        cache += kvb
    if cfg.arch_type in ("ssm", "hybrid"):
        cache += (cfg.num_layers * b_loc * cfg.ssm_nheads * cfg.ssm_head_dim
                  * cfg.ssm_state * 4 * 2)
    return act_p + cache


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    bytes_ideal: float
    collective_bytes: float
    tokens: int
    cfg: ModelConfig
    hw: HardwareConfig = TPU_V5E
    memory_stats: dict = field(default_factory=dict)
    collectives_by_kind: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def memory_ideal_s(self) -> float:
        """Memory term if the Pallas kernels keep loop carries in VMEM
        (the TPU-target number; memory_s is the CPU-path HLO count)."""
        return self.bytes_ideal / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def model_flops_total(self) -> float:
        return model_flops(self.cfg, self.tokens)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_flops × chips): how much compiled compute is
        'useful'.  <1 means remat/dispatch overhead; >1 means the compiler
        under-counts (e.g. fused ops)."""
        hlo_total = self.flops_per_device * self.chips
        return self.model_flops_total / max(hlo_total, 1.0)

    def as_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_ideal_s": self.memory_ideal_s,
            "bytes_ideal": self.bytes_ideal,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_ratio,
            "tokens": self.tokens,
            "memory_stats": self.memory_stats,
            "collectives_by_kind": self.collectives_by_kind,
        }


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            cfg: ModelConfig, compiled, tokens: int, *, kind: str = "train",
            global_batch: int = 0, seq_len: int = 0,
            capacity: int = 0) -> Roofline:
    """XLA's cost_analysis counts while bodies ONCE, so scanned-layer
    programs under-report by ~L×.  The trip-count-aware HLO walk in
    ``hlo_cost`` is the authoritative source; the raw cost_analysis numbers
    are kept in memory_stats for reference."""
    from repro.launch import hlo_cost

    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    hc = hlo_cost.analyze_hlo(text)
    mem = compiled.memory_analysis()
    mem_stats = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        "xla_cost_flops": float(ca.get("flops", 0.0)),
        "xla_cost_bytes": float(ca.get("bytes accessed", 0.0)),
        "num_whiles": hc.num_whiles,
        "trip_counts": hc.trip_counts,
    }
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=hc.flops,
        bytes_per_device=hc.bytes,
        bytes_ideal=analytic_bytes(cfg, kind, global_batch, seq_len, chips,
                                   capacity),
        collective_bytes=hc.collective_bytes,
        tokens=tokens, cfg=cfg,
        memory_stats=mem_stats,
        collectives_by_kind=hc.collectives_by_kind,
    )
