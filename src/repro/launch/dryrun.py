import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, print memory/cost analysis, and dump the roofline
record to benchmarks/results/<arch>__<shape>__<mesh>.json.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --reshard

The XLA flag above MUST precede every other import: jax locks the device
count on first initialization.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES  # noqa: E402
from repro.launch import analysis  # noqa: E402
from repro.launch.mesh import make_mesh, make_production_mesh  # noqa: E402
from repro.launch.specs import SkipPair, build_program, reshard_program  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


def _tokens(shape_name: str) -> int:
    sc = INPUT_SHAPES[shape_name]
    if sc.kind == "decode":
        return sc.global_batch          # one token per sequence
    return sc.global_batch * sc.seq_len


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             gen_mode: str = "2d", verbose: bool = True,
             tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    t0 = time.perf_counter()
    try:
        fn, args, in_shard, out_shard, meta = build_program(
            arch, shape_name, mesh, gen_mode=gen_mode)
    except SkipPair as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": str(e)}
        _save(rec, arch, shape_name, mesh_name, tag)
        if verbose:
            print(f"SKIP {arch} × {shape_name} × {mesh_name}: {e}")
        return rec

    with mesh:
        lowered = jax.jit(fn, in_shardings=in_shard,
                          out_shardings=out_shard).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    if verbose:
        print(f"== {arch} × {shape_name} × {mesh_name} ({meta['kind']}) ==")
        print(mem)                       # proves it fits (or not)
        ca = compiled.cost_analysis() or {}
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})

    sc = INPUT_SHAPES[shape_name]
    roof = analysis.analyze(arch, shape_name, mesh_name, chips,
                            meta["cfg"], compiled, _tokens(shape_name),
                            kind=meta["kind"], global_batch=sc.global_batch,
                            seq_len=sc.seq_len,
                            capacity=meta.get("capacity", 0))
    rec = roof.as_dict()
    rec.update(status="ok", kind=meta["kind"],
               lower_s=t_lower, compile_s=t_compile, gen_mode=gen_mode)
    rec["cfg"] = None  # not JSON-serializable; arch name suffices
    del rec["memory_stats"]["alias_bytes"]
    rec["memory_stats"] = roof.memory_stats
    _save(rec, arch, shape_name, mesh_name, tag)
    if verbose:
        print(f"roofline: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"dominant={roof.dominant} "
              f"useful_ratio={roof.useful_ratio:.2f} "
              f"[lower {t_lower:.0f}s compile {t_compile:.0f}s]")
    return rec


def run_reshard(arch: str, *, multi_pod: bool = False, gen_mode: str = "tp",
                verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    fn, args, in_shard, out_shard, meta = reshard_program(
        arch, mesh, gen_mode=gen_mode)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_shard,
                           out_shardings=out_shard).lower(*args).compile()
    stats = analysis.parse_collectives(compiled.as_text())
    rec = {
        "arch": arch, "shape": f"reshard_{gen_mode}", "mesh": mesh_name,
        "status": "ok", "kind": "reshard",
        "collective_bytes_per_device": stats.modeled_bytes,
        "collectives_by_kind": stats.by_kind(),
        "collective_s": stats.modeled_bytes / analysis.TPU_V5E.ici_bw,
    }
    _save(rec, arch, f"reshard_{gen_mode}", mesh_name, "")
    if verbose:
        print(f"== reshard {arch} × {mesh_name} -> {gen_mode} ==")
        print(f"collective bytes/device: {stats.modeled_bytes/1e9:.3f} GB "
              f"-> {rec['collective_s']*1e3:.1f} ms over ICI")
    return rec


def _save(rec: dict, arch: str, shape: str, mesh_name: str, tag: str):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(
        RESULTS_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")
    clean = {k: v for k, v in rec.items() if k != "cfg"}
    with open(path, "w") as f:
        json.dump(clean, f, indent=1, default=str)


def run_pipeline_demo(arch: str = "yi-6b", microbatches: int = 8,
                      verbose: bool = True) -> dict:
    """PP demo: lower + compile a pipelined LM train step on a
    (pipe=4, data=8, model=8) = 256-chip mesh — proves the paper's "PP"
    feature composes with the rest of the stack at production scale."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.specs import params_structs
    from repro.models import layers as Lx
    from repro.models import transformer as T
    from repro.sharding.pipeline import pipeline_forward

    cfg = get_config(arch)
    mesh = make_mesh((4, 8, 8), ("pipe", "data", "model"))
    pstruct = params_structs(cfg)
    b, s = 32, 4096
    mb = b // microbatches

    def layer_fn(lp, h, cos, sin):
        return T._layer_train(cfg, lp, h, cos, sin)

    def loss_fn(params, tokens, cos, sin):
        x = Lx.embed_tokens(params, cfg, tokens)
        x = pipeline_forward(layer_fn, params["layers"], x, mesh,
                             microbatches=microbatches, consts=(cos, sin))
        x = Lx.norm_apply(params["ln_f"], cfg, x)
        logits = Lx.unembed(params, cfg, x)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.take_along_axis(lp[:, :-1], tokens[:, 1:, None],
                                  axis=-1)[..., 0]
        return -jnp.mean(tgt)

    grad_fn = jax.grad(loss_fn)
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    cos, sin = jax.eval_shape(
        lambda: T._rope(cfg, T._positions(cfg, mb, s)))
    with mesh:
        compiled = jax.jit(grad_fn).lower(
            pstruct, tok,
            jax.ShapeDtypeStruct(cos.shape, cos.dtype),
            jax.ShapeDtypeStruct(sin.shape, sin.dtype)).compile()
    stats = analysis.parse_collectives(compiled.as_text())
    rec = {"arch": arch, "shape": f"pipeline_mb{microbatches}",
           "mesh": "4x8x8", "status": "ok", "kind": "pipeline",
           "collective_bytes_per_device": stats.modeled_bytes,
           "bubble_fraction": (4 - 1) / (microbatches + 4 - 1)}
    _save(rec, arch, f"pipeline_mb{microbatches}", "4x8x8", "")
    if verbose:
        print(f"== pipeline demo {arch} × 4x8x8 mesh (mb={microbatches}) ==")
        print(compiled.memory_analysis())
        print(f"collective bytes/device {stats.modeled_bytes/1e9:.2f} GB, "
              f"bubble {(4-1)/(microbatches+3):.1%}")
    return rec


def run_graphs() -> None:
    """Print the declared RL dataflow graphs (paper Fig. 1 as RLGraph) —
    the static view of what the GraphExecutor schedules; no compilation."""
    from repro.core.partial import build_partial_graph
    from repro.core.ppo_trainer import build_ppo_graph
    from repro.core.trainer import build_grpo_graph

    for build in (build_grpo_graph, build_ppo_graph, build_partial_graph):
        g = build()
        print(g.describe())
        print("  edges:")
        for src, fld, dst in g.edges():
            print(f"    {src} --{fld}--> {dst}")
        print()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--gen-mode", default="2d", choices=["2d", "tp"])
    ap.add_argument("--reshard", action="store_true")
    ap.add_argument("--pipeline", action="store_true")
    ap.add_argument("--graph", action="store_true",
                    help="print the declared RL dataflow graphs and exit")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.graph:
        run_graphs()
        return

    if args.pipeline:
        run_pipeline_demo(args.arch or "yi-6b")
        return

    if args.reshard:
        archs = [args.arch] if args.arch else ASSIGNED_ARCHS
        for a in archs:
            run_reshard(a, multi_pod=args.multi_pod, gen_mode="tp")
        return

    pairs = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        pairs = [(args.arch, args.shape)]

    failures = []
    for a, s in pairs:
        try:
            run_pair(a, s, multi_pod=args.multi_pod, gen_mode=args.gen_mode,
                     tag=args.tag)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((a, s, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} × {s}: {e[:200]}")
        raise SystemExit(1)
    print("\nall pairs lowered + compiled OK")


if __name__ == "__main__":
    main()
