"""ShapeDtypeStruct stand-ins + sharding bundles for every
(architecture × input shape) program — no device allocation anywhere.

``build_program(arch, shape_name, mesh, ...)`` returns:
    fn         — the python callable to jit (train_step / prefill / decode)
    args       — tuple of ShapeDtypeStruct pytrees
    in_shard   — matching tree of NamedSharding
    out_shard  — optional
    meta       — dict (program kind, capacity, notes)

Decode shapes lower ``serve_step`` (ONE token against a seq_len KV cache).
``long_500k`` requires sub-quadratic attention: SSM/hybrid run natively; the
full-attention archs run with an 8192 sliding-window ring cache (config
override recorded in meta); whisper skips it (fixed 1500-frame audio context
— recorded in DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig, RLConfig, ShapeConfig
from repro.core import grpo
from repro.models.model import build_model
from repro.optim import adamw_init
from repro.sharding import batch_partition, cache_specs, param_specs

LONG_CTX_WINDOW = 8192


class SkipPair(Exception):
    """This (arch, shape) pair is skipped by design (see DESIGN.md)."""


def effective_config(arch: str, shape_name: str) -> ModelConfig:
    cfg = get_config(arch)
    if shape_name == "long_500k":
        if cfg.arch_type == "audio":
            raise SkipPair(
                "whisper: 500k-token decode context does not exist "
                "(fixed 1500-frame audio context)")
        if not cfg.is_attention_free and cfg.hybrid_attn_period == 0:
            win = cfg.sliding_window or LONG_CTX_WINDOW
            cfg = cfg.replace(sliding_window=min(win, LONG_CTX_WINDOW))
    return cfg


def decode_capacity(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if cfg.sliding_window and shape.seq_len > cfg.sliding_window:
        return cfg.sliding_window       # ring buffer
    return shape.seq_len


# ---------------------------------------------------------------------------
# struct builders (all via eval_shape / ShapeDtypeStruct — zero allocation)
# ---------------------------------------------------------------------------

def params_structs(cfg: ModelConfig):
    model = build_model(cfg)
    return jax.eval_shape(
        functools.partial(model.init, cfg), jax.random.PRNGKey(0))


def opt_structs(params):
    return jax.eval_shape(adamw_init, params)


def _extras_structs(cfg: ModelConfig, b: int) -> dict:
    out = {}
    if cfg.arch_type == "vlm":
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.arch_type == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return out


def train_batch_structs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "response_mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        "advantages": jax.ShapeDtypeStruct((b,), jnp.float32),
        "old_logp": jax.ShapeDtypeStruct((b, s - 1), jnp.float32),
        "ref_logp": jax.ShapeDtypeStruct((b, s - 1), jnp.float32),
    }
    batch.update(_extras_structs(cfg, b))
    return batch


def _batch_specs(cfg: ModelConfig, structs: dict, mesh) -> dict:
    out = {}
    for k, v in structs.items():
        bax = batch_partition(mesh, v.shape[0])
        out[k] = P(bax, *([None] * (v.ndim - 1)))
    return out


def cache_structs(cfg: ModelConfig, b: int, capacity: int):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(cfg, b, capacity))


# ---------------------------------------------------------------------------
# program bundles
# ---------------------------------------------------------------------------

def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def build_program(arch: str, shape_name: str, mesh, *,
                  gen_mode: str = "2d", rl: RLConfig | None = None):
    cfg = effective_config(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    rl = rl or RLConfig()
    model = build_model(cfg)
    pstruct = params_structs(cfg)

    if shape.kind == "train":
        tspecs = param_specs(cfg, pstruct, mesh, stage="train")
        ostruct = opt_structs(pstruct)
        ospecs = opt_structs_specs(tspecs, ostruct)
        bstruct = train_batch_structs(cfg, shape)
        bspecs = _batch_specs(cfg, bstruct, mesh)
        fn = grpo.make_train_step(cfg, rl)
        args = (pstruct, ostruct, bstruct)
        in_shard = (_named(mesh, tspecs), _named(mesh, ospecs),
                    _named(mesh, bspecs))
        out_shard = (_named(mesh, tspecs), _named(mesh, ospecs), None)
        meta = {"kind": "train", "cfg": cfg}
        return fn, args, in_shard, out_shard, meta

    gspecs = param_specs(cfg, pstruct, mesh, stage="gen", gen_mode=gen_mode)
    if shape.kind == "prefill":
        b, s = shape.global_batch, shape.seq_len
        cstruct = cache_structs(cfg, b, s)
        cspecs = cache_specs(cfg, cstruct, mesh)
        bstruct = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        bstruct.update(_extras_structs(cfg, b))
        bspecs = _batch_specs(cfg, bstruct, mesh)

        def prefill_fn(params, batch, cache):
            return model.prefill(params, cfg, batch, cache)

        args = (pstruct, bstruct, cstruct)
        in_shard = (_named(mesh, gspecs), _named(mesh, bspecs),
                    _named(mesh, cspecs))
        out_shard = (None, _named(mesh, cspecs))
        meta = {"kind": "prefill", "cfg": cfg}
        return prefill_fn, args, in_shard, out_shard, meta

    # decode
    b, s = shape.global_batch, shape.seq_len
    cap = decode_capacity(cfg, shape)
    cstruct = cache_structs(cfg, b, cap)
    cspecs = cache_specs(cfg, cstruct, mesh)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    bax = batch_partition(mesh, b)

    def serve_step(params, cache, tokens, pos):
        return model.decode(params, cfg, cache, tokens, pos)

    args = (pstruct, cstruct, tok, pos)
    in_shard = (_named(mesh, gspecs), _named(mesh, cspecs),
                NamedSharding(mesh, P(bax, None)), NamedSharding(mesh, P()))
    out_shard = (None, _named(mesh, cspecs))
    meta = {"kind": "decode", "cfg": cfg, "capacity": cap,
            "window": cfg.sliding_window}
    return serve_step, args, in_shard, out_shard, meta


def opt_structs_specs(param_specs_tree, ostruct):
    """AdamW state specs: step replicated, moments shaped like params."""
    from repro.optim.adamw import AdamWState
    return AdamWState(step=P(), mu=param_specs_tree, nu=param_specs_tree)


def reshard_program(arch: str, mesh, gen_mode: str = "tp"):
    """The resharding flow as a lowered program: identity jit mapping
    train-layout weights to generation-layout weights (XLA emits the
    all-gather schedule — Figure 5 step 1-2 at production scale)."""
    cfg = get_config(arch)
    pstruct = params_structs(cfg)
    tspecs = param_specs(cfg, pstruct, mesh, stage="train")
    gspecs = param_specs(cfg, pstruct, mesh, stage="gen", gen_mode=gen_mode)
    fn = lambda p: p
    return (fn, (pstruct,), (_named(mesh, tspecs),),
            _named(mesh, gspecs), {"kind": "reshard", "cfg": cfg})
