"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes/collectives by ~L×.  This
module walks the post-SPMD HLO text, propagates multipliers through the call
graph (while bodies × known_trip_count, fusions × 1) and accumulates:

  * flops            — 2 · prod(result) · contraction for every dot
  * bytes            — result + operand buffer sizes of every non-fused,
                       non-view instruction (the HBM traffic model: every HLO
                       buffer is written once and read per use)
  * collectives      — modeled ring bytes per device for all-gather /
                       all-reduce / reduce-scatter / all-to-all /
                       collective-permute

All values are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")
_CALLED_RE = re.compile(r"(calls|to_apply|condition|body)=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count["\']?:\s*\{\s*["\']?n["\']?:\s*"?(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_VIEW_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "iota", "after-all", "reshape", "copy-start", "copy-done",
             "partition-id", "replica-id", "rng-bit-generator"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start", "ragged-all-to-all"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _ring_factor(kind: str, g: int) -> float:
    kind = kind.replace("-start", "")
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind == "all-gather":
        return (g - 1) / g
    if kind == "reduce-scatter":
        return float(g - 1)
    if kind in ("all-to-all", "ragged-all-to-all"):
        return (g - 1) / g
    return 1.0


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives_by_kind: dict = field(default_factory=dict)
    num_whiles: int = 0
    trip_counts: list = field(default_factory=list)
    raw_flops: float = 0.0            # without trip-count multipliers


def _matching_paren(s: str, start: int) -> int:
    """Index just past the paren group opening at ``s[start] == '('``."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_instr(line: str) -> Instr | None:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):              # tuple type (may contain comments)
        end = _matching_paren(rest, 0)
        type_str = rest[:end]
        rest = rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest = rest[sp + 1:].lstrip()
    p = rest.find("(")
    if p < 0:
        return None
    opcode = rest[:p].strip()
    end = _matching_paren(rest, p)
    op_str = rest[p + 1:end - 1]
    attrs = rest[end:]
    return Instr(name, type_str, opcode, _OPERAND_RE.findall(op_str), attrs)


def parse_module(text: str):
    """Returns (computations: name -> [Instr], entry_name, shape_table)."""
    comps, cur, entry = {}, None, None
    shape_table = {}
    for line in text.splitlines():
        stripped = line.strip()
        m = _COMP_RE.match(stripped) if "{" in line else None
        if m and ("->" in line):
            cur = comps.setdefault(m.group(1), [])
            if stripped.startswith("ENTRY"):
                entry = m.group(1)
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        inst = _parse_instr(line)
        if inst is None:
            continue
        cur.append(inst)
        shape_table[inst.name] = inst.type_str
    return comps, entry, shape_table


def analyze_hlo(text: str) -> HloCost:
    comps, entry, shapes = parse_module(text)
    cost = HloCost()
    if entry is None:
        return cost

    # computations reached through fusions/reductions: flops yes, bytes no
    work = [(entry, 1.0, True)]        # (comp, multiplier, count_bytes)
    seen_whiles = set()
    while work:
        cname, mult, count_bytes = work.pop()
        for inst in comps.get(cname, ()):  # noqa: B020
            op = inst.opcode
            # --- call graph ------------------------------------------------
            refs = _CALLED_RE.findall(inst.attrs)
            if op == "while":
                tm = _TRIP_RE.search(inst.attrs)
                trip = float(tm.group(1)) if tm else 1.0
                if inst.name not in seen_whiles:
                    seen_whiles.add(inst.name)
                    cost.num_whiles += 1
                    cost.trip_counts.append(trip)
                for kind, ref in refs:
                    work.append((ref, mult * (trip if kind == "body" else trip),
                                 count_bytes))
                continue
            for kind, ref in refs:
                # fusion interiors don't touch HBM; reduce bodies are tiny
                work.append((ref, mult, False))

            # --- flops -----------------------------------------------------
            if op in ("dot", "convolution"):
                result = 1
                for d in _first_shape_dims(inst.type_str):
                    result *= d
                contract = 1
                cm = _CONTRACT_RE.search(inst.attrs)
                if cm and inst.operands:
                    lhs_dims = _first_shape_dims(
                        shapes.get(inst.operands[0], ""))
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
                f = 2.0 * result * contract
                cost.flops += mult * f
                cost.raw_flops += f

            # --- collectives ------------------------------------------------
            if op in _COLLECTIVES:
                buf = _shape_bytes(inst.type_str)
                if op.endswith("-start"):
                    buf //= 2          # start tuples carry (operand, result)
                gm = _GROUPS_RE.search(inst.attrs)
                g = int(gm.group(2)) if gm else 1
                moved = buf * _ring_factor(op, g)
                cost.collective_bytes += mult * moved
                k = op.replace("-start", "")
                d = cost.collectives_by_kind.setdefault(
                    k, {"count": 0.0, "modeled_bytes": 0.0})
                d["count"] += mult
                d["modeled_bytes"] += mult * moved

            # --- bytes -----------------------------------------------------
            if count_bytes and op not in _VIEW_OPS:
                b = _shape_bytes(inst.type_str)
                for o in inst.operands:
                    b += _shape_bytes(shapes.get(o, ""))
                cost.bytes += mult * b
    return cost
