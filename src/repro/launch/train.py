"""Training launcher — end-to-end GRPO on a selectable architecture.

CPU-scale entry point (runs for real):
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --iterations 50 --global-batch 8

Production entry point (same code path, production mesh — requires a real
TPU slice; on this container use ``--dry-run`` which delegates to dryrun.py):
    python -m repro.launch.train --arch qwen2.5-32b --mesh 16x16
"""
from __future__ import annotations

import argparse
import json
import time

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.configs.base import RLConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--algorithm", default="grpo",
                    choices=["grpo", "dapo", "ppo"])
    ap.add_argument("--iterations", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--num-generations", type=int, default=4)
    ap.add_argument("--max-prompt-len", type=int, default=16)
    ap.add_argument("--max-response-len", type=int, default=16)
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--kl-coef", type=float, default=1e-3)
    ap.add_argument("--temperature", type=float, default=1.0,
                    help="sampling temperature for rollout generation")
    ap.add_argument("--clip-eps", type=float, default=0.2,
                    help="PPO/GRPO ratio clip epsilon (DAPO uses "
                         "clip_eps_high for the upper side)")
    ap.add_argument("--serve-max-slots", type=int, default=0,
                    help="serving engine slot count (0 = RLConfig default)")
    ap.add_argument("--serve-block-size", type=int, default=0,
                    help="paged KV cache block size in tokens "
                         "(0 = RLConfig default)")
    ap.add_argument("--num-nodes", type=int, default=4)
    ap.add_argument("--no-transfer-dock", action="store_true")
    ap.add_argument("--no-allgather-swap", action="store_true")
    ap.add_argument("--no-stage-fusion", action="store_true",
                    help="dispatch independent ready graph nodes "
                         "sequentially instead of concurrently")
    ap.add_argument("--partial-rollout", action="store_true",
                    help="budgeted long-tail generation across iterations "
                         "(runs on the continuous-batching serving engine; "
                         "resume = mid-sequence re-prefill)")
    ap.add_argument("--rollout-engine", default=None,
                    choices=["sync", "serving"],
                    help="generation engine (default: RLConfig default; "
                    "partial rollout always uses serving)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable serving prefix-cache block sharing")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="serving chunked prefill: max prefill tokens per "
                    "engine step (0 = whole-prompt admission)")
    ap.add_argument("--host-tier-blocks", type=int, default=0,
                    help="host-RAM KV tier capacity in blocks (0 = off): "
                    "preempted/suspended KV swaps to host and back instead "
                    "of being recomputed")
    ap.add_argument("--serve-sampling-seed", type=int, default=0,
                    help="run key for counter-based per-request sampling "
                    "streams (serve_sampling_seed): same seed => bitwise "
                    "replayable rollouts, independent of scheduling")
    ap.add_argument("--serve-top-p", type=float, default=1.0,
                    help="nucleus sampling mass, fused into the decode "
                    "step (serve_top_p; 1.0 = off; both engines)")
    ap.add_argument("--serve-top-k", type=int, default=0,
                    help="top-k truncation before sampling (serve_top_k; "
                    "0 = off; both engines)")
    ap.add_argument("--rollout-budget", type=int, default=8,
                    help="tokens per sequence per iteration "
                         "(--partial-rollout)")
    ap.add_argument("--print-graph", action="store_true",
                    help="print the declared RLGraph and exit")
    ap.add_argument("--task", default="pattern",
                    choices=["pattern", "arithmetic"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                    "(stage spans, serving steps, dock byte counters) — "
                    "open at ui.perfetto.dev; see docs/observability.md")
    ap.add_argument("--log-json", default=None)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="N",
                    help="also snapshot full train state to --checkpoint "
                    "after every N completed iterations (enables exact "
                    "--resume mid-run; see docs/resilience.md)")
    ap.add_argument("--resume", default=None,
                    help="checkpoint path to restore from: a train-state "
                    "snapshot resumes the run at the saved iteration "
                    "(exact replay); a legacy params-only checkpoint "
                    "restores just the policy weights")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="deterministic fault injection, e.g. "
                    "'stage.ref_inference@1,swap.in@2,dock.put@3:fatal' — "
                    "site@hit[:kind] entries; see docs/resilience.md")
    args = ap.parse_args()
    if args.partial_rollout and args.algorithm == "ppo":
        ap.error("--partial-rollout implements the GRPO family; "
                 "it cannot be combined with --algorithm ppo")

    # imports deferred so --help never initializes jax
    from repro.checkpoint import (is_train_state, load_pytree,
                                  load_train_state, save_pytree,
                                  save_train_state)
    from repro.core.partial import PartialRolloutTrainer
    from repro.core.ppo_trainer import PPOTrainer
    from repro.core.trainer import GRPOTrainer
    from repro.data.prompts import PromptDataset, arithmetic_task, pattern_task
    from repro.resilience import FatalFault, FaultPlan

    if args.checkpoint_every and not args.checkpoint:
        ap.error("--checkpoint-every needs --checkpoint PATH")
    faults = FaultPlan.parse(args.fault_plan) if args.fault_plan else None

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = cfg.replace(dtype="float32", remat=False)
    rl = RLConfig(
        algorithm=args.algorithm,
        num_generations=args.num_generations,
        max_prompt_len=args.max_prompt_len,
        max_response_len=args.max_response_len,
        lr=args.lr, kl_coef=args.kl_coef,
        temperature=args.temperature,
        clip_eps=args.clip_eps,
        use_transfer_dock=not args.no_transfer_dock,
        use_allgather_swap=not args.no_allgather_swap,
        stage_fusion=not args.no_stage_fusion,
        partial_rollout=args.partial_rollout,
        num_warehouses=args.num_nodes,
        serve_prefix_cache=not args.no_prefix_cache,
        serve_prefill_chunk=args.prefill_chunk,
        serve_host_tier_blocks=args.host_tier_blocks,
        serve_sampling_seed=args.serve_sampling_seed,
        serve_top_p=args.serve_top_p,
        serve_top_k=args.serve_top_k,
    )
    if args.rollout_engine:
        rl = rl.replace(rollout_engine=args.rollout_engine)
    if args.serve_max_slots:
        rl = rl.replace(serve_max_slots=args.serve_max_slots)
    if args.serve_block_size:
        rl = rl.replace(serve_block_size=args.serve_block_size)
    if args.trace:
        rl = rl.replace(trace_path=args.trace)
    if args.print_graph:
        # static declaration — no model/optimizer init needed; node ids
        # match the trainer's worker placement for --num-nodes
        from repro.core.partial import build_partial_graph
        from repro.core.ppo_trainer import build_ppo_graph
        from repro.core.trainer import build_grpo_graph
        build = (build_partial_graph if args.partial_rollout
                 else build_ppo_graph if args.algorithm == "ppo"
                 else build_grpo_graph)
        print(build(0, 1 % args.num_nodes, 2 % args.num_nodes).describe())
        return

    task = pattern_task() if args.task == "pattern" else arithmetic_task()
    ds = PromptDataset(task, max_prompt_len=rl.max_prompt_len, seed=args.seed)
    # every algorithm is a graph DECLARATION over the same executor: the
    # trainer classes differ only in which RLGraph they build
    if args.partial_rollout:
        trainer = PartialRolloutTrainer(cfg, rl, ds, budget=args.rollout_budget,
                                        num_nodes=args.num_nodes,
                                        seed=args.seed, faults=faults)
    elif args.algorithm == "ppo":
        trainer = PPOTrainer(cfg, rl, ds, num_nodes=args.num_nodes,
                             seed=args.seed, faults=faults)
    else:
        trainer = GRPOTrainer(cfg, rl, ds, num_nodes=args.num_nodes,
                              seed=args.seed, faults=faults)
    start = 0
    if args.resume:
        if is_train_state(args.resume):
            start = load_train_state(args.resume, trainer)
            print(f"resumed train state from {args.resume} "
                  f"(iteration {start})")
        else:
            trainer.params = load_pytree(args.resume, trainer.params)
            print(f"restored policy from {args.resume}")

    log = []
    for it in range(start, args.iterations):
        t0 = time.perf_counter()
        try:
            st = trainer.iteration(args.global_batch)
        except FatalFault as err:
            # injected unrecoverable fault (chaos testing): flush what we
            # have so a --resume run can be compared against the log, then
            # exit with a distinct status the CI smoke asserts on
            print(f"fatal injected fault: {err}")
            if args.log_json:
                with open(args.log_json, "w") as f:
                    json.dump(log, f, indent=1)
            raise SystemExit(3)
        tput = trainer.throughput(st, args.global_batch)
        rec = {
            "iteration": it, "reward": st.reward_mean, "loss": st.loss,
            "kl": st.kl, "tokens_per_s_per_device": tput,
            "ete_s": time.perf_counter() - t0,
            "dispatch_s": st.dispatch["simulated_dispatch_time_s"],
            "reshard_swap_s": st.reshard.get("modeled_swap_time_s", 0.0),
        }
        log.append(rec)
        print(f"[{it:4d}] reward={st.reward_mean:6.3f} loss={st.loss:8.4f} "
              f"kl={st.kl:.5f} T={tput:8.1f} tok/s/dev "
              f"ete={rec['ete_s']:6.2f}s")
        if args.checkpoint_every and (it + 1) % args.checkpoint_every == 0:
            save_train_state(args.checkpoint, trainer, iteration=it + 1)

    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump(log, f, indent=1)
    if args.trace:
        print(f"wrote trace to {trainer.export_trace()} "
              f"(open at https://ui.perfetto.dev)")
    if args.checkpoint:
        if args.checkpoint_every:
            save_train_state(args.checkpoint, trainer,
                             iteration=args.iterations)
        else:
            save_pytree(args.checkpoint, trainer.params, step=args.iterations)
        print(f"saved checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()
