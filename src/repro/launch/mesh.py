"""Production meshes.

Single pod:  (16, 16)    over ("data", "model")        — 256 chips.
Multi-pod:   (2, 16, 16) over ("pod", "data", "model") — 512 chips.

A FUNCTION (not a module constant) so importing this module never touches
jax device state — only ``dryrun.py`` sets the 512-host-device XLA flag.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh():
    """1-device mesh with the production axis names (CPU examples/tests)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
