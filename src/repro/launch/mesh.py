"""Production meshes.

Single pod:  (16, 16)    over ("data", "model")        — 256 chips.
Multi-pod:   (2, 16, 16) over ("pod", "data", "model") — 512 chips.

FUNCTIONS (not module constants) so importing this module never touches
jax device state — only ``dryrun.py`` sets the 512-host-device XLA flag.

``make_mesh`` is the ONE version-tolerant constructor: newer jax exposes
``jax.sharding.AxisType`` and accepts ``axis_types=``; jax 0.4.x does not
(meshes are implicitly Auto there), so we feature-detect once and every
call site in src/, examples/, benchmarks/ and tests/ goes through here.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-tolerant ``jax.make_mesh`` with Auto axis types everywhere
    the installed jax supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape, axes):
    """Version-tolerant ``jax.sharding.AbstractMesh`` (device-free mesh for
    sharding rules).  Newer jax: ``AbstractMesh(shape, axes, axis_types=…)``;
    jax 0.4.x: ``AbstractMesh(((name, size), …))``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.sharding.AbstractMesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU examples/tests)."""
    return make_mesh((1, 1), ("data", "model"))
