from repro.checkpoint.io import (is_train_state, load_pytree,  # noqa: F401
                                 load_train_state, save_pytree,
                                 save_train_state)
