"""Pytree checkpointing: one .npz of leaves + a JSON treedef of paths.

Arrays are fetched to host (fully replicated view) before writing; restore
re-places them with ``jax.device_put`` against target shardings when given.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_pytree(path: str, tree, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)
    meta = {"keys": sorted(flat), "step": step}
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def load_pytree(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat_like[0]))
    for (pathk, leaf), sh in zip(flat_like[0], shard_flat):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in pathk)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)
