"""Pytree checkpointing: one .npz of leaves + a JSON treedef of paths.

Arrays are fetched to host (fully replicated view) before writing; restore
re-places them with ``jax.device_put`` against target shardings when given.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_pytree(path: str, tree, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)
    meta = {"keys": sorted(flat), "step": step}
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def load_pytree(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat_like[0]))
    for (pathk, leaf), sh in zip(flat_like[0], shard_flat):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in pathk)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)


# ---------------------------------------------------------------------------
# Iteration-level train state — checkpoint/resume for the resilience layer.
#
# A train-state checkpoint is taken BETWEEN iterations and captures every
# input the next iteration reads: policy/reference/optimizer pytrees, the
# trainer and serving-engine PRNG keys, the dataset RNG, the transfer dock's
# rows + readiness metadata (live state for partial rollout, where samples
# span iterations), and the partial-rollout carryover (pending sequences,
# per-sample metas, the persistent index counter).  ``--resume`` from one
# replays the remaining iterations bit-identically (docs/resilience.md).
# ---------------------------------------------------------------------------

TRAIN_STATE_KIND = "train_state"


def _unflatten_like(data, prefix: str, like):
    """Rebuild ``like``'s structure from npz entries ``prefix/<path>``."""
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathk, leaf in flat_like[0]:
        key = prefix + "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                                for k in pathk)
        leaves.append(jnp.asarray(data[key], dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)


def is_train_state(path: str) -> bool:
    """True when ``path`` holds a full train-state checkpoint (vs the legacy
    params-only ``save_pytree`` format) — lets ``--resume`` accept both."""
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".json"
    if not os.path.exists(meta_path):
        return False
    with open(meta_path) as f:
        return json.load(f).get("kind") == TRAIN_STATE_KIND


def save_train_state(path: str, trainer, *, iteration: int) -> None:
    """Snapshot ``trainer`` after ``iteration`` completed iterations."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {}
    for prefix, tree in (("params/", trainer.params),
                         ("ref/", trainer.ref_params),
                         ("opt/", trainer.opt_state)):
        for k, v in _flatten_with_paths(tree).items():
            arrays[prefix + k] = v
    arrays["key"] = np.asarray(jax.device_get(trainer.key))

    meta = {
        "kind": TRAIN_STATE_KIND,
        "iteration": int(iteration),
        "iters_run": int(trainer._iters_run),
        "dataset_rng": trainer.dataset.rng.bit_generator.state,
        "metas": {str(i): m for i, m in
                  getattr(trainer, "_metas", {}).items()},
        "plen": int(getattr(trainer, "_plen", 0)),
    }

    # serving-engine cursor state: only the request-id counter (it feeds
    # default per-request stream seeds).  Sampling keys are counter-derived
    # from the static run key — rebuilt from config at construction — so
    # there is no mutable key state to snapshot; the sync rollout engine is
    # stateless between iterations either way.
    if trainer.actor.engine_kind == "serving":
        meta["serve_next_rid"] = int(trainer.actor.engine._next_rid)

    # transfer dock — rows plus readiness/consumed metadata.  For trainers
    # that clear the dock each iteration this is empty at a boundary; for
    # partial rollout it is live cross-iteration state.
    dock = trainer.dock
    dock_fields = []
    # canonical (field, idx) order: warehouse insertion order follows stage
    # completion order, which is schedule-dependent under fused dispatch —
    # checkpoint content must depend only on state, not schedule history
    for wh in dock.warehouses:
        for fld in sorted(wh.store):
            rows = wh.store[fld]
            for idx in sorted(rows):
                arrays[f"dock/{fld}/{int(idx)}"] = np.asarray(rows[idx])
                dock_fields.append([fld, int(idx)])
    meta["dock"] = {
        "rows": dock_fields,
        "ready": {s: {str(i): sorted(f) for i, f in sorted(ctl.ready.items())}
                  for s, ctl in dock.controllers.items()},
        "consumed": {s: sorted(int(i) for i in ctl.consumed)
                     for s, ctl in dock.controllers.items()},
        "proto": {fld: [list(shape), np.dtype(dt).str]
                  for fld, (shape, dt) in dock._proto.items()},
    }

    # partial-rollout carryover (absent on plain GRPO/PPO trainers)
    partials = getattr(trainer, "partials", None)
    if partials is not None:
        meta["partials"] = {str(i): [int(t) for t in st.generated]
                            for i, st in partials.items()}
        meta["next_idx"] = int(trainer._next_idx)
        for i, st in partials.items():
            arrays[f"partials/{int(i)}/prompt"] = np.asarray(st.prompt)

    np.savez(path, **arrays)
    with open((path[:-4] if path.endswith(".npz") else path) + ".json",
              "w") as f:
        json.dump(meta, f)


def load_train_state(path: str, trainer) -> int:
    """Restore ``trainer`` in place from a ``save_train_state`` snapshot;
    returns the number of iterations already completed (resume point)."""
    npz_path = path if path.endswith(".npz") else path + ".npz"
    data = np.load(npz_path)
    with open((path[:-4] if path.endswith(".npz") else path) + ".json") as f:
        meta = json.load(f)
    if meta.get("kind") != TRAIN_STATE_KIND:
        raise ValueError(f"{path} is not a train-state checkpoint "
                         f"(kind={meta.get('kind')!r}); use load_pytree")

    trainer.params = _unflatten_like(data, "params/", trainer.params)
    trainer.ref_params = _unflatten_like(data, "ref/", trainer.ref_params)
    trainer.opt_state = _unflatten_like(data, "opt/", trainer.opt_state)
    # the reference worker holds the ref pytree by reference — re-point it
    trainer.ref.params = trainer.ref_params
    trainer.key = jnp.asarray(data["key"], dtype=trainer.key.dtype)
    trainer._iters_run = int(meta["iters_run"])
    trainer.dataset.rng.bit_generator.state = meta["dataset_rng"]
    trainer._metas = {int(i): m for i, m in meta.get("metas", {}).items()}
    if meta.get("plen"):
        trainer._plen = int(meta["plen"])

    if trainer.actor.engine_kind == "serving" and "serve_next_rid" in meta:
        trainer.actor.engine._next_rid = int(meta["serve_next_rid"])

    dock = trainer.dock
    dock.clear()
    dmeta = meta.get("dock", {})
    for fld, (shape, dt) in dmeta.get("proto", {}).items():
        dock._proto[fld] = (tuple(shape), np.dtype(dt))
    for fld, idx in dmeta.get("rows", []):
        dock._wh(int(idx)).put(fld, int(idx), data[f"dock/{fld}/{int(idx)}"])
    for state, ready in dmeta.get("ready", {}).items():
        ctl = dock.controllers[state]
        for idx, fields in ready.items():
            ctl.ready[int(idx)] = set(fields)
    for state, consumed in dmeta.get("consumed", {}).items():
        dock.controllers[state].consumed = set(consumed)

    if "partials" in meta and hasattr(trainer, "partials"):
        from repro.core.partial import PartialState
        trainer.partials = {
            int(i): PartialState(
                prompt=np.asarray(data[f"partials/{int(i)}/prompt"]),
                generated=list(gen))
            for i, gen in meta["partials"].items()}
        trainer._next_idx = int(meta["next_idx"])

    return int(meta["iteration"])
