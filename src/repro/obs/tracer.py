"""Structured tracer with a Chrome-trace / Perfetto JSON exporter.

Event model (a subset of the Trace Event Format that Perfetto renders):

  * span    — a named interval (``ph: "X"`` complete event, ``ts`` + ``dur``
    in microseconds).  Recorded when the span EXITS, so nested spans appear
    after their children in the raw list; the exporter sorts by ``ts``,
    which restores timeline order (Perfetto reconstructs nesting from
    interval containment per track).
  * instant — a point event (``ph: "i"``, thread scope).
  * counter — a sampled multi-series value (``ph: "C"``); Perfetto draws
    each distinct counter name as its own track with one line per series.

Clock: ``time.perf_counter_ns`` relative to the tracer's construction, so
``ts`` is monotonic, immune to wall-clock steps, and starts near zero
(Perfetto's viewport opens on the data).  ``pid`` is always 0 (one-process
system); ``tid`` is a small dense alias of the Python thread ident, assigned
in first-use order so the main thread is track 0.

Disabled mode is the contract the serving hot loop relies on: ``span()``
returns a module-level singleton null context (no allocation), ``instant``/
``counter`` return before touching any state, and nothing is ever appended —
``tests/test_obs.py`` pins all three properties with a counting probe.
"""
from __future__ import annotations

import json
import threading
import time


class _NullSpan:
    """Singleton no-op context manager returned by a disabled tracer."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """Live span: times its ``with`` body and records one complete event."""
    __slots__ = ("_tr", "name", "cat", "args", "_t0")

    def __init__(self, tr: "Tracer", name: str, cat: str, args):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.args = args           # caller may still mutate before __exit__
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = self._tr._now()
        return self

    def __exit__(self, *exc):
        tr = self._tr
        tr._append({"name": self.name, "cat": self.cat, "ph": "X",
                    "ts": self._t0, "dur": tr._now() - self._t0,
                    "pid": 0, "tid": tr._tid(),
                    "args": self.args if self.args is not None else {}})
        return False


class Tracer:
    """Process-local structured event log (spans / instants / counters)."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._events: list[dict] = []  # guarded-by: _lock
        self._lock = threading.Lock()
        self._epoch_ns = time.perf_counter_ns()
        self._tids: dict[int, int] = {}  # guarded-by: _lock

    # -- clock / identity ---------------------------------------------------
    def _now(self) -> float:
        """Microseconds since tracer construction (monotonic)."""
        return (time.perf_counter_ns() - self._epoch_ns) / 1e3

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _append(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    # -- control ------------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    @property
    def events(self) -> list[dict]:
        """Snapshot copy of the raw event list (append order)."""
        with self._lock:
            return list(self._events)

    # -- emission -----------------------------------------------------------
    def span(self, name: str, cat: str = "repro", args: dict | None = None):
        """Context manager timing its body as one complete event.  Disabled:
        returns the singleton ``NULL_SPAN`` — no allocation, no event."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "repro",
                args: dict | None = None) -> None:
        if not self.enabled:
            return
        self._append({"name": name, "cat": cat, "ph": "i", "s": "t",
                      "ts": self._now(), "pid": 0, "tid": self._tid(),
                      "args": args or {}})

    def counter(self, name: str, values: dict, cat: str = "repro") -> None:
        """One sample of a (multi-series) counter track.  ``values`` maps
        series name -> number; pass CUMULATIVE values so the track reads as
        a running total (Perfetto shows deltas on hover)."""
        if not self.enabled:
            return
        self._append({"name": name, "cat": cat, "ph": "C",
                      "ts": self._now(), "pid": 0, "tid": self._tid(),
                      "args": dict(values)})

    # -- export -------------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome-trace JSON object: events sorted by ``ts`` (monotone), as
        chrome://tracing and https://ui.perfetto.dev both ingest."""
        evs = sorted(self.events, key=lambda e: e["ts"])
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome-trace JSON to ``path`` and return ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path
