"""MetricsRegistry — named counters / gauges / histograms, snapshot() dict.

Aggregate accounting (totals + distributions), complementary to the
tracer's timeline: the tracer answers WHEN, the registry answers HOW MUCH.
Always on — every operation is a dict lookup plus an add/append, cheap
enough for the serving hot loop — and thread-safe under one lock.

``snapshot()`` is the machine-readable contract: a plain, JSON-serializable
dict with deterministically sorted keys, histograms summarized to
count/sum/mean/min/max + nearest-rank percentiles.  ``engine.stats()`` and
the ``BENCH_*.json`` artifacts are built from it.

Percentile definition (nearest-rank, the one documented in
docs/observability.md): pq over n sorted samples is the element at index
``ceil(q * n) - 1`` — the smallest sample >= q of the distribution.  No
interpolation, so every reported percentile is a value that actually
occurred.
"""
from __future__ import annotations

import math
import threading

PERCENTILES = (0.5, 0.9, 0.95, 0.99)


def nearest_rank(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile over an ASCENDING-sorted non-empty list."""
    idx = max(0, math.ceil(q * len(sorted_vals)) - 1)
    return sorted_vals[min(idx, len(sorted_vals) - 1)]


class MetricsRegistry:
    """Named counters (monotone ints), gauges (last/max value), histograms
    (raw observations, summarized at snapshot time)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}  # guarded-by: _lock
        self._gauges: dict[str, float] = {}  # guarded-by: _lock
        self._hists: dict[str, list[float]] = {}  # guarded-by: _lock

    # -- write --------------------------------------------------------------
    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def set(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def set_max(self, name: str, value: float) -> None:
        """Gauge that only ratchets upward (e.g. max prefill tokens/step)."""
        with self._lock:
            if value > self._gauges.get(name, value - 1):
                self._gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            self._hists.setdefault(name, []).append(float(value))

    # -- read ---------------------------------------------------------------
    def value(self, name: str, default=0):
        """Current counter (or gauge) value; ``default`` when never set."""
        with self._lock:
            if name in self._counters:
                return self._counters[name]
            return self._gauges.get(name, default)

    def percentile(self, name: str, q: float):
        """Nearest-rank percentile of histogram ``name``; None if empty."""
        with self._lock:
            vals = self._hists.get(name)
            if not vals:
                return None
            return nearest_rank(sorted(vals), q)

    def summarize(self, name: str) -> dict:
        """Histogram summary dict (the snapshot shape); {} if unobserved."""
        with self._lock:
            vals = list(self._hists.get(name, ()))
        if not vals:
            return {}
        vals.sort()
        out = {
            "count": len(vals),
            "sum": float(sum(vals)),
            "mean": float(sum(vals) / len(vals)),
            "min": vals[0],
            "max": vals[-1],
        }
        for q in PERCENTILES:
            out[f"p{int(q * 100)}"] = nearest_rank(vals, q)
        return out

    def snapshot(self) -> dict:
        """Deterministic, JSON-serializable view of everything recorded:
        ``{"counters": {...}, "gauges": {...}, "histograms": {name:
        summary}}`` with sorted keys.  Repeated calls with no writes in
        between return equal dicts (pinned by tests)."""
        with self._lock:
            counters = dict(sorted(self._counters.items()))
            gauges = dict(sorted(self._gauges.items()))
            hist_names = sorted(self._hists)
        return {
            "counters": counters,
            "gauges": gauges,
            "histograms": {n: self.summarize(n) for n in hist_names},
        }

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
