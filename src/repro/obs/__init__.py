"""repro.obs — zero-dependency telemetry: structured tracing + metrics.

Two small, orthogonal pieces (see docs/observability.md for the catalog):

  * ``Tracer``          — process-local structured event log (spans /
    instants / counters on a monotonic clock) with a Chrome-trace /
    Perfetto JSON exporter.  Thread-safe; a DISABLED tracer is a cheap
    no-op (singleton null span, zero events, zero state growth) so the
    serving hot loop can stay instrumented unconditionally.
  * ``MetricsRegistry`` — named counters / gauges / histograms with a
    ``snapshot()`` dict contract.  Always on (plain dict arithmetic);
    this is where ``engine.stats()`` percentiles and the
    ``BENCH_*.json`` artifacts come from.

The module-level default tracer (``get_tracer()``) is DISABLED; every
instrumented constructor accepts ``tracer=`` and falls back to it, so code
is traceable without plumbing until a driver (``train.py --trace`` /
``RLConfig.trace_path``) creates an enabled tracer and threads it through.
"""
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import NULL_SPAN, Tracer

_DEFAULT = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The process-local default tracer (disabled unless a driver enables
    it).  Instrumented code uses this when no tracer is injected."""
    return _DEFAULT


__all__ = ["Tracer", "MetricsRegistry", "NULL_SPAN", "get_tracer"]
