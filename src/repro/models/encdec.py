"""Whisper-style encoder-decoder [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the assignment carve-out:
``batch["frames"]`` carries precomputed frame embeddings (B, encoder_seq,
d_model).  Positions are sinusoidal (deviation from whisper's learned decoder
positions, recorded in DESIGN.md) so any decode length lowers with one
parameter set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    ne, nd = cfg.encoder_layers, cfg.num_layers
    return {
        **L.embed_init(cfg, ks[0]),
        "enc_layers": {
            "ln1": L.norm_init(cfg, cfg.d_model, ne),
            "attn": L.attn_init(cfg, ks[1], ne),
            "ln2": L.norm_init(cfg, cfg.d_model, ne),
            "mlp": L.mlp_init(cfg, ks[2], ne),
        },
        "enc_ln": L.norm_init(cfg, cfg.d_model),
        "dec_layers": {
            "ln1": L.norm_init(cfg, cfg.d_model, nd),
            "attn": L.attn_init(cfg, ks[3], nd),
            "lnx": L.norm_init(cfg, cfg.d_model, nd),
            "xattn": L.attn_init(cfg, ks[4], nd),
            "ln2": L.norm_init(cfg, cfg.d_model, nd),
            "mlp": L.mlp_init(cfg, ks[5], nd),
        },
        "ln_f": L.norm_init(cfg, cfg.d_model),
    }


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    b, s, _ = frames.shape
    pos = L.sinusoid_positions(jnp.arange(s), cfg.d_model)
    x = frames.astype(L.cdtype(cfg)) + pos[None].astype(L.cdtype(cfg))

    def body(h, lp):
        h = h + L.attn_train(lp["attn"], cfg, L.norm_apply(lp["ln1"], cfg, h),
                             None, None, causal=False)
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.norm_apply(params["enc_ln"], cfg, x)


def _embed_dec(params, cfg, tokens, offset=0):
    b, s = tokens.shape
    x = L.embed_tokens(params, cfg, tokens)
    pos = L.sinusoid_positions(jnp.arange(s) + offset, cfg.d_model)
    return x + pos[None].astype(x.dtype)


def forward(params: dict, cfg: ModelConfig, batch: dict):
    enc_out = encode(params, cfg, batch["frames"])
    x = _embed_dec(params, cfg, batch["tokens"])

    def body(h, lp):
        h = h + L.attn_train(lp["attn"], cfg, L.norm_apply(lp["ln1"], cfg, h),
                             None, None)
        ek, ev = L.cross_kv(lp["xattn"], cfg, enc_out)
        h = h + L.cross_attn_train(lp["xattn"], cfg,
                                   L.norm_apply(lp["lnx"], cfg, h), ek, ev)
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.norm_apply(params["ln_f"], cfg, x)
    # logits stay in the compute dtype: an f32 cast here would seed f32
    # cotangents through the WHOLE backward residual chain (§Perf log).
    return L.unembed(params, cfg, x)


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    n = cfg.num_layers
    dt = L.cdtype(cfg)
    return {
        "k": jnp.zeros((n, batch, capacity, kv, hd), dt),
        "v": jnp.zeros((n, batch, capacity, kv, hd), dt),
        "xk": jnp.zeros((n, batch, cfg.encoder_seq, kv, hd), dt),
        "xv": jnp.zeros((n, batch, cfg.encoder_seq, kv, hd), dt),
    }


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    enc_out = encode(params, cfg, batch["frames"])
    x = _embed_dec(params, cfg, batch["tokens"])
    b, s, _ = x.shape
    cap = cache["k"].shape[2]

    def body(h, lp):
        y, kk, vv = L.attn_prefill(lp["attn"], cfg,
                                   L.norm_apply(lp["ln1"], cfg, h), None, None)
        h = h + y
        ek, ev = L.cross_kv(lp["xattn"], cfg, enc_out)
        h = h + L.cross_attn_train(lp["xattn"], cfg,
                                   L.norm_apply(lp["lnx"], cfg, h), ek, ev)
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        kk = kk[:, -cap:] if s >= cap else jnp.pad(
            kk, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
        vv = vv[:, -cap:] if s >= cap else jnp.pad(
            vv, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
        return h, (kk, vv, ek, ev)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec_layers"])
    x = L.norm_apply(params["ln_f"], cfg, x[:, -1:])
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"k": ks, "v": vs, "xk": xks, "xv": xvs}


def decode(params: dict, cfg: ModelConfig, cache: dict, tokens: jnp.ndarray,
           pos: jnp.ndarray):
    b = tokens.shape[0]
    cap = cache["k"].shape[2]
    x = L.embed_tokens(params, cfg, tokens)
    pe = L.sinusoid_positions(jnp.asarray(pos, jnp.int32)[None], cfg.d_model)
    x = x + pe[None].astype(x.dtype)                    # (1,1,d) broadcast
    slot = jax.lax.rem(pos, cap)
    valid = jnp.broadcast_to((jnp.arange(cap) <= pos)[None], (b, cap))

    def body(h, xs):
        lp, kc, vc, xk, xv = xs
        y, kc, vc = L.attn_decode(lp["attn"], cfg,
                                  L.norm_apply(lp["ln1"], cfg, h),
                                  None, None, kc, vc, slot, valid)
        h = h + y
        h = h + L.cross_attn_decode(lp["xattn"], cfg,
                                    L.norm_apply(lp["lnx"], cfg, h), xk, xv)
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.norm_apply(params["ln_f"], cfg, x)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
