"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block
[arXiv:2411.15242].

The shared transformer block (attn + MLP, a single weight set) is applied
after every ``hybrid_attn_period`` mamba layers.  Structure for scan
friendliness: the first ``n_groups * period`` mamba layers are scanned as
(n_groups, period, ...) with the shared block at each group boundary; the
remaining ``tail`` layers are a plain mamba scan.

The shared block is genuine WEIGHT ALIASING — one pytree leaf reused at
n_groups sites — which the resharding flow must gather exactly once, while
its KV cache is per-site (n_groups, B, S, KV, hd).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M


def _split(cfg: ModelConfig):
    period = cfg.hybrid_attn_period
    n_groups = cfg.num_layers // period
    tail = cfg.num_layers - n_groups * period
    return period, n_groups, tail


def init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 5)
    return {
        **L.embed_init(cfg, ks[0]),
        "mamba": M.block_init(cfg, ks[1], cfg.num_layers),
        "shared": {
            "ln1": L.norm_init(cfg, cfg.d_model),
            "attn": L.attn_init(cfg, ks[2]),
            "ln2": L.norm_init(cfg, cfg.d_model),
            "mlp": L.mlp_init(cfg, ks[3]),
        },
        "ln_f": L.norm_init(cfg, cfg.d_model),
    }


def _group_params(cfg, mamba):
    period, n_groups, tail = _split(cfg)
    ng = n_groups * period
    grouped = jax.tree.map(
        lambda v: v[:ng].reshape((n_groups, period) + v.shape[1:]), mamba)
    tail_p = jax.tree.map(lambda v: v[ng:], mamba)
    return grouped, tail_p


def _shared_train(sp, cfg, h, cos, sin):
    h = h + L.attn_train(sp["attn"], cfg, L.norm_apply(sp["ln1"], cfg, h),
                         cos, sin)
    h = h + L.mlp_apply(sp["mlp"], cfg, L.norm_apply(sp["ln2"], cfg, h))
    return h


def forward(params: dict, cfg: ModelConfig, batch: dict):
    x = L.embed_tokens(params, cfg, batch["tokens"])
    b, s, _ = x.shape
    cos, sin = L.rope_for(cfg, jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b, s)))
    grouped, tail_p = _group_params(cfg, params["mamba"])
    shared = params["shared"]

    def inner(h, lp):
        return M.block_train(lp, cfg, h), None

    def group_body(h, gp):
        h, _ = jax.lax.scan(inner, h, gp)
        return _shared_train(shared, cfg, h, cos, sin), None

    if cfg.remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = jax.lax.scan(group_body, x, grouped)
    if _split(cfg)[2]:
        tail_body = jax.checkpoint(inner, prevent_cse=False) if cfg.remat else inner
        x, _ = jax.lax.scan(tail_body, x, tail_p)
    x = L.norm_apply(params["ln_f"], cfg, x)
    # logits stay in the compute dtype: an f32 cast here would seed f32
    # cotangents through the WHOLE backward residual chain (§Perf log).
    return L.unembed(params, cfg, x)


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    period, n_groups, tail = _split(cfg)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    dt = L.cdtype(cfg)
    return {
        "mamba": M.init_cache(cfg, batch, capacity),
        "attn_k": jnp.zeros((n_groups, batch, capacity, kv, hd), dt),
        "attn_v": jnp.zeros((n_groups, batch, capacity, kv, hd), dt),
    }


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    x = L.embed_tokens(params, cfg, batch["tokens"])
    b, s, _ = x.shape
    cap = cache["attn_k"].shape[2]
    period, n_groups, tail = _split(cfg)
    cos, sin = L.rope_for(cfg, jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b, s)))
    grouped, tail_p = _group_params(cfg, params["mamba"])
    shared = params["shared"]

    def inner(h, lp):
        out, conv, ssm = M.block_prefill(lp, cfg, h)
        return out, (conv, ssm)

    def group_body(h, gp):
        h, mcache = jax.lax.scan(inner, h, gp)
        y, kk, vv = L.attn_prefill(shared["attn"], cfg,
                                   L.norm_apply(shared["ln1"], cfg, h),
                                   cos, sin)
        h = h + y
        h = h + L.mlp_apply(shared["mlp"], cfg,
                            L.norm_apply(shared["ln2"], cfg, h))
        kk = kk[:, -cap:] if s >= cap else jnp.pad(
            kk, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
        vv = vv[:, -cap:] if s >= cap else jnp.pad(
            vv, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
        return h, (mcache, kk, vv)

    if cfg.remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    x, (mcache_g, ks, vs) = jax.lax.scan(group_body, x, grouped)
    # flatten (n_groups, period, ...) mamba caches back to (L, ...)
    conv_g, ssm_g = mcache_g
    merge = lambda v: v.reshape((-1,) + v.shape[2:])
    conv = jax.tree.map(merge, conv_g)
    ssm = merge(ssm_g)
    if tail:
        tb = jax.checkpoint(inner, prevent_cse=False) if cfg.remat else inner
        x, (conv_t, ssm_t) = jax.lax.scan(tb, x, tail_p)
        conv = jax.tree.map(lambda a, t: jnp.concatenate([a, t]), conv, conv_t)
        ssm = jnp.concatenate([ssm, ssm_t])
    x = L.norm_apply(params["ln_f"], cfg, x[:, -1:])
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"mamba": {"conv": conv, "ssm": ssm},
                    "attn_k": ks, "attn_v": vs}


def decode(params: dict, cfg: ModelConfig, cache: dict, tokens: jnp.ndarray,
           pos: jnp.ndarray):
    x = L.embed_tokens(params, cfg, tokens)
    b = x.shape[0]
    cap = cache["attn_k"].shape[2]
    period, n_groups, tail = _split(cfg)
    cos, sin = L.rope_for(cfg, jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32)[None, None], (b, 1)))
    slot = jax.lax.rem(pos, cap)
    valid = jnp.broadcast_to((jnp.arange(cap) <= pos)[None], (b, cap))
    grouped, tail_p = _group_params(cfg, params["mamba"])
    mc = cache["mamba"]
    ng = n_groups * period
    take_g = lambda v: v[:ng].reshape((n_groups, period) + v.shape[1:])
    take_t = lambda v: v[ng:]
    conv_g = jax.tree.map(take_g, mc["conv"])
    ssm_g = take_g(mc["ssm"])
    conv_t = jax.tree.map(take_t, mc["conv"])
    ssm_t = take_t(mc["ssm"])
    shared = params["shared"]

    def inner(h, xs):
        lp, conv, ssm = xs
        out, conv, ssm = M.block_decode(lp, cfg, h, conv, ssm)
        return out, (conv, ssm)

    def group_body(h, xs):
        gp, gconv, gssm, kc, vc = xs
        h, (nconv, nssm) = jax.lax.scan(inner, h, (gp, gconv, gssm))
        y, kc, vc = L.attn_decode(shared["attn"], cfg,
                                  L.norm_apply(shared["ln1"], cfg, h),
                                  cos, sin, kc, vc, slot, valid)
        h = h + y
        h = h + L.mlp_apply(shared["mlp"], cfg,
                            L.norm_apply(shared["ln2"], cfg, h))
        return h, (nconv, nssm, kc, vc)

    x, (nconv_g, nssm_g, ks, vs) = jax.lax.scan(
        group_body, x, (grouped, conv_g, ssm_g, cache["attn_k"],
                        cache["attn_v"]))
    merge = lambda v: v.reshape((-1,) + v.shape[2:])
    conv = jax.tree.map(merge, nconv_g)
    ssm = merge(nssm_g)
    if tail:
        x, (nconv_t, nssm_t) = jax.lax.scan(inner, x, (tail_p, conv_t, ssm_t))
        conv = jax.tree.map(lambda a, t: jnp.concatenate([a, t]), conv, nconv_t)
        ssm = jnp.concatenate([ssm, nssm_t])
    x = L.norm_apply(params["ln_f"], cfg, x)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"mamba": {"conv": conv, "ssm": ssm},
                    "attn_k": ks, "attn_v": vs}
