"""Dense decoder-only transformer (families: dense, vlm).

Layers are scanned (stacked params) so 80-layer configs lower to O(1) HLO.
Supports GQA, RoPE / M-RoPE (vlm), QKV bias, sliding-window attention, and a
ring-buffered KV cache for long-context decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    n = cfg.num_layers
    return {
        **L.embed_init(cfg, ks[0]),
        "layers": {
            "ln1": L.norm_init(cfg, cfg.d_model, n),
            "attn": L.attn_init(cfg, ks[1], n),
            "ln2": L.norm_init(cfg, cfg.d_model, n),
            "mlp": L.mlp_init(cfg, ks[2], n),
        },
        "ln_f": L.norm_init(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def _positions(cfg: ModelConfig, b: int, s: int, offset=0):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.arch_type == "vlm":
        # M-RoPE: vision prefix laid out on a (t=0, h, w) grid, text sequential.
        p = cfg.vision_tokens
        side = max(int(p ** 0.5), 1)
        idx = jnp.arange(s, dtype=jnp.int32)
        is_vis = idx < p
        t = jnp.where(is_vis, 0, idx)
        h = jnp.where(is_vis, idx // side, idx)
        w = jnp.where(is_vis, idx % side, idx)
        pos3 = jnp.stack([t, h, w])[:, None, :] + offset
        return jnp.broadcast_to(pos3, (3, b, s))
    return pos


def _rope(cfg: ModelConfig, positions):
    if cfg.arch_type == "vlm":
        return L.mrope_for(cfg, positions)
    return L.rope_for(cfg, positions)


def _decode_pos_valid(cfg: ModelConfig, pos, b: int, cap: int):
    """Normalize a decode position — () shared by the batch (synchronized
    rollout) or (B,) per-sequence (continuous-batching serving) — into
    (offset for _positions, write slot, (B, cap) validity mask)."""
    pos = jnp.asarray(pos, jnp.int32)
    offset = pos if pos.ndim == 0 else pos[:, None]
    slot = jax.lax.rem(pos, cap)
    ar = jnp.arange(cap)
    pcol = pos if pos.ndim == 0 else pos[:, None]
    valid = ar <= pcol  # ring overwrite keeps this exact for cap == window
    if cfg.sliding_window > 0 and cap > cfg.sliding_window:
        valid &= ar > pcol - cfg.sliding_window
    valid = jnp.broadcast_to(valid if pos.ndim else valid[None], (b, cap))
    return offset, slot, valid


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _layer_train(cfg, lp, x, cos, sin):
    x = x + L.attn_train(lp["attn"], cfg, L.norm_apply(lp["ln1"], cfg, x),
                         cos, sin)
    x = x + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, x))
    return x


def _embed_in(params, cfg, batch):
    x = L.embed_tokens(params, cfg, batch["tokens"])
    if cfg.arch_type == "vlm" and "vision_embeds" in batch:
        p = batch["vision_embeds"].shape[1]
        x = jnp.concatenate(
            [batch["vision_embeds"].astype(x.dtype), x[:, p:]], axis=1)
        x = L.constrain_batch(x)   # re-anchor: concat drops the constraint
    return x


# ---------------------------------------------------------------------------
# train / prefill / decode
# ---------------------------------------------------------------------------

def forward_hidden(params: dict, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    """Final hidden states (B, S, d) — used by the PPO critic value head."""
    x = _embed_in(params, cfg, batch)
    b, s, _ = x.shape
    cos, sin = _rope(cfg, _positions(cfg, b, s))

    def body(h, lp):
        return _layer_train(cfg, lp, h, cos, sin), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.norm_apply(params["ln_f"], cfg, x)


def forward(params: dict, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    x = forward_hidden(params, cfg, batch)
    # logits stay in the compute dtype: an f32 cast here would seed f32
    # cotangents through the WHOLE backward residual chain (§Perf log).
    return L.unembed(params, cfg, x)


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    kv, hd, n = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    dt = L.cdtype(cfg)
    return {
        "k": jnp.zeros((n, batch, capacity, kv, hd), dt),
        "v": jnp.zeros((n, batch, capacity, kv, hd), dt),
    }


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict,
            last=None):
    """Ingest the prompt; returns (last-token logits, filled cache).

    ``last`` (traced () int32, optional) selects which position's logits to
    return instead of the final one — the serving engine's bucketed admission
    prefill right-pads prompts to a power-of-2 length and needs the logits of
    the last REAL token (causality keeps rows < ``last`` + their KV
    bit-identical to an unpadded prefill)."""
    x = _embed_in(params, cfg, batch)
    b, s, _ = x.shape
    cap = cache["k"].shape[2]
    cos, sin = _rope(cfg, _positions(cfg, b, s))

    def body(h, lp):
        y, k, v = L.attn_prefill(lp["attn"], cfg,
                                 L.norm_apply(lp["ln1"], cfg, h), cos, sin)
        h = h + y
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        # store last `cap` positions (ring semantics when cap < s)
        k = k[:, -cap:] if s >= cap else jnp.pad(
            k, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
        v = v[:, -cap:] if s >= cap else jnp.pad(
            v, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
        return h, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    xl = x[:, -1:] if last is None else jax.lax.dynamic_slice_in_dim(
        x, last, 1, axis=1)
    x = L.norm_apply(params["ln_f"], cfg, xl)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"k": ks, "v": vs}


def prefill_paged(params: dict, cfg: ModelConfig, pool_k: jnp.ndarray,
                  pool_v: jnp.ndarray, table: jnp.ndarray,
                  tokens: jnp.ndarray, start, *, block_size: int, last):
    """Continuation prefill of one CHUNK for one serving slot.

    tokens: (1, C) the chunk (right-padded to a bucket); start: () int32 —
    KV rows already resident for this slot (prefix-shared blocks and/or
    earlier chunks); table: (MB,) int32 the slot's block-table row; ``last``:
    () int32 — index WITHIN the chunk whose logits to return (the engine
    only consumes them on the final chunk, to sample the first token).

    Returns (logits (1, V) f32, k_rows (n, C, kv, hd), v_rows) — the caller
    scatters the chunk's KV rows into the pool, exactly like ``decode_paged``
    returns one token's rows.  Row content is bitwise identical to the same
    rows of a whole-prompt ``prefill`` on the jnp attention path (see
    ``kernels.ops.chunk_prefill_attention``), which is what lets prefix
    sharing + chunked prefill preserve the serving engine's greedy
    bit-compatibility with ``RolloutEngine``."""
    x = _embed_in(params, cfg, {"tokens": tokens})
    b, c, _ = x.shape
    start = jnp.asarray(start, jnp.int32)
    cos, sin = _rope(cfg, _positions(cfg, b, c, offset=start))

    def body(h, xs):
        lp, pk, pv = xs
        y, k1, v1 = L.attn_prefill_paged(lp["attn"], cfg,
                                         L.norm_apply(lp["ln1"], cfg, h),
                                         cos, sin, pk, pv, table, start,
                                         block_size)
        h = h + y
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        return h, (k1, v1)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], pool_k, pool_v))
    xl = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)
    x = L.norm_apply(params["ln_f"], cfg, xl)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, ks[:, 0], vs[:, 0]


def paged_window(cfg: ModelConfig, cap: int) -> int:
    """Effective sliding window for a paged decode over a logical capacity of
    ``cap`` rows — mirrors ``_decode_pos_valid``'s static gate, which only
    applies the window once the cache could outlive it."""
    return (cfg.sliding_window
            if cfg.sliding_window > 0 and cap > cfg.sliding_window else 0)


def decode_paged(params: dict, cfg: ModelConfig, pool_k: jnp.ndarray,
                 pool_v: jnp.ndarray, tables: jnp.ndarray,
                 tokens: jnp.ndarray, pos: jnp.ndarray, *, block_size: int):
    """One decode step against the PAGED KV pool (continuous-batching
    serving).  tokens: (S, 1); pos: (S,) int32 per-slot cached rows;
    pool_k/pool_v: (n, R, kv, hd) row pools; tables: (S, MB) int32.

    Returns (logits, new_k, new_v) where new_k/new_v (n, S, kv, hd) are this
    token's KV rows for the engine to scatter into the pool — the model
    never materializes a dense per-slot cache view (contrast ``decode``,
    which consumes one; that path remains for the synchronized rollout
    engine and as the serving bit-compatibility oracle)."""
    x = L.embed_tokens(params, cfg, tokens)
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    window = paged_window(cfg, tables.shape[1] * block_size)
    cos, sin = _rope(cfg, _positions(cfg, b, 1, offset=pos[:, None]))

    def body(h, xs):
        lp, pk, pv = xs
        y, k1, v1 = L.attn_decode_paged(lp["attn"], cfg,
                                        L.norm_apply(lp["ln1"], cfg, h),
                                        cos, sin, pk, pv, tables, pos,
                                        block_size, window)
        h = h + y
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        return h, (k1, v1)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], pool_k, pool_v))
    x = L.norm_apply(params["ln_f"], cfg, x)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, ks, vs


def decode(params: dict, cfg: ModelConfig, cache: dict, tokens: jnp.ndarray,
           pos: jnp.ndarray):
    """One decode step.  tokens: (B, 1); pos: () int32 — absolute position of
    the incoming token (same for the whole batch; synchronized RL rollout) —
    or (B,) int32 per-sequence positions (continuous-batching serving).
    """
    x = L.embed_tokens(params, cfg, tokens)
    b = x.shape[0]
    cap = cache["k"].shape[2]
    offset, slot, valid = _decode_pos_valid(cfg, pos, b, cap)
    cos, sin = _rope(cfg, _positions(cfg, b, 1, offset=offset))

    def body(h, xs):
        lp, kc, vc = xs
        y, kc, vc = L.attn_decode(lp["attn"], cfg,
                                  L.norm_apply(lp["ln1"], cfg, h),
                                  cos, sin, kc, vc, slot, valid)
        h = h + y
        h = h + L.mlp_apply(lp["mlp"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = L.norm_apply(params["ln_f"], cfg, x)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"k": ks, "v": vs}
