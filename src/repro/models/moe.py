"""Mixture-of-Experts decoder (mixtral-8x7b, llama4-maverick, qwen3-moe).

Routing is capacity-based dispatch (the TPU-idiomatic dense-einsum form used
by t5x/MaxText "dropping" MoE): tokens are split into groups of
``_MOE_GROUP`` along the sequence, each group computes a top-k one-hot
dispatch tensor of shape (group, E, capacity) and the expert FFN runs as an
einsum over (E, capacity) token slots — so compiled FLOPs scale with ACTIVE
tokens (× capacity_factor), not with E.  Expert dims shard over the mesh
"model" axis (EP); XLA emits the all-to-all-equivalent resharding collectives.

Small-batch decode (b·k << E, e.g. long_500k top-1) switches to a
weight-gather path: reading k experts' weights per token is the true
memory-bound cost; the dense dispatch form would overcount FLOPs by E/(b·k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

_MOE_GROUP = 256


def moe_init(cfg: ModelConfig, key, layers: int) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": L._normal(ks[0], (layers, d, e), 1 / np.sqrt(d), jnp.float32),
        "w_gate": L._normal(ks[1], (layers, e, d, f), 1 / np.sqrt(d),
                            L.cdtype(cfg)),
        "w_up": L._normal(ks[2], (layers, e, d, f), 1 / np.sqrt(d),
                          L.cdtype(cfg)),
        "w_down": L._normal(ks[3], (layers, e, f, d), 1 / np.sqrt(f),
                            L.cdtype(cfg)),
    }


def _route(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    """x: (B, S, d) -> (gates (B,S,k), idx (B,S,k), probs (B,S,E))."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates.astype(x.dtype), idx, probs


def _aux_loss(cfg: ModelConfig, probs: jnp.ndarray, idx: jnp.ndarray):
    """Switch-style load-balance loss."""
    e = cfg.num_experts
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)      # (B,S,k,E)
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))     # fraction routed
    return e * jnp.sum(me * ce)


def moe_apply_gmm(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    """Dropless expert FFN via grouped matmul (the paper's GMM kernel).

    Tokens are replicated per selected expert, sorted by expert id with
    group boundaries padded to the GMM tile, run through three grouped
    matmuls, then un-permuted and gate-combined.  No capacity drops — exact
    routing — at the cost of data-dependent padding (<= E*tile rows)."""
    from repro.kernels import ops
    from repro.kernels.gmm import pad_groups

    b, s, d = x.shape
    e, k, f = cfg.num_experts, cfg.experts_per_token, cfg.d_ff
    gates, idx, probs = _route(p, cfg, x)
    aux = _aux_loss(cfg, probs, idx)
    t = b * s
    xt = x.reshape(t, d)
    xk = jnp.repeat(xt, k, axis=0)                       # (T*k, d)
    gid = idx.reshape(t * k)
    # NOTE: single-layer weights here — callers pass per-layer slices
    tile = 64
    xs, sizes, order, dest = pad_groups(xk, gid, e, tile_t=tile)
    gate = ops.gmm(xs, p["w_gate"], sizes, tile_t=tile)
    up = ops.gmm(xs, p["w_up"], sizes, tile_t=tile)
    h = ops.swiglu(gate, up)
    ys = ops.gmm(h, p["w_down"], sizes, tile_t=tile)
    yk = jnp.zeros((t * k, d), ys.dtype).at[order].set(ys[dest])
    y = jnp.einsum("tkd,tk->td", yk.reshape(t, k, d),
                   gates.reshape(t, k).astype(ys.dtype))
    return y.reshape(b, s, d), aux


def moe_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    """Expert FFN.  x: (B, S, d) -> (y, aux_loss).  Dispatch-form (capacity
    einsum) by default; ``cfg.moe_impl == "gmm"`` selects the dropless
    grouped-matmul path."""
    if cfg.moe_impl == "gmm":
        return moe_apply_gmm(p, cfg, x)
    b, s, d = x.shape
    e, k, f = cfg.num_experts, cfg.experts_per_token, cfg.d_ff
    g = min(_MOE_GROUP, s)
    while s % g:
        g //= 2
    ng = s // g
    cap = max(int(np.ceil(k * g * cfg.moe_capacity_factor / e)), 1)

    gates, idx, probs = _route(p, cfg, x)
    aux = _aux_loss(cfg, probs, idx)

    xg = x.reshape(b * ng, g, d)
    gates = gates.reshape(b * ng, g, k)
    idx = idx.reshape(b * ng, g, k)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)        # (n, g, k, E)
    pos = jnp.cumsum(onehot.reshape(b * ng, g * k, e), axis=1).reshape(
        b * ng, g, k, e) * onehot - 1                       # slot per (tok,k)
    keep = (pos >= 0) & (pos < cap)
    dispatch = (jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                               dtype=x.dtype)[..., :cap]
                * onehot[..., None].astype(x.dtype))        # (n,g,k,E,C)
    combine = dispatch * gates[..., None, None]
    dispatch = jnp.sum(dispatch, axis=2)                    # (n,g,E,C)
    combine = jnp.sum(combine, axis=2)

    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg)         # (n,E,C,d)
    gate = jnp.einsum("necd,edf->necf", xe, p["w_gate"])
    up = jnp.einsum("necd,edf->necf", xe, p["w_up"])
    h = L.ops.swiglu(gate.reshape(-1, f), up.reshape(-1, f)).reshape(gate.shape)
    ye = jnp.einsum("necf,efd->necd", h, p["w_down"])
    y = jnp.einsum("ngec,necd->ngd", combine, ye)
    return y.reshape(b, s, d), aux


def moe_decode_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    """One-token expert FFN.  x: (B, 1, d)."""
    b, _, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    gates, idx, _ = _route(p, cfg, x)
    if b * k * 4 <= e:
        # weight-gather path: read only the selected experts' weights
        idxf = idx.reshape(b, k)
        wg = jnp.take(p["w_gate"], idxf, axis=0)            # (b,k,d,f)
        wu = jnp.take(p["w_up"], idxf, axis=0)
        wd = jnp.take(p["w_down"], idxf, axis=0)
        xt = x[:, 0]                                        # (b,d)
        gate = jnp.einsum("bd,bkdf->bkf", xt, wg)
        up = jnp.einsum("bd,bkdf->bkf", xt, wu)
        h = L.ops.swiglu(gate.reshape(b * k, -1),
                         up.reshape(b * k, -1)).reshape(gate.shape)
        yk = jnp.einsum("bkf,bkfd->bkd", h, wd)
        y = jnp.einsum("bkd,bk->bd", yk, gates[:, 0].astype(yk.dtype))
        return y[:, None]
    # dispatch path: group along the BATCH (one group of b tokens), so the
    # expert einsum costs E*C ~= b*k*cf token-slots, not b*E.
    y, _ = moe_apply(p, cfg, x.reshape(1, b, d))
    return y.reshape(b, 1, d)


# ---------------------------------------------------------------------------
# model API (reuses the dense skeleton, swapping the MLP for MoE)
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    n = cfg.num_layers
    return {
        **L.embed_init(cfg, ks[0]),
        "layers": {
            "ln1": L.norm_init(cfg, cfg.d_model, n),
            "attn": L.attn_init(cfg, ks[1], n),
            "ln2": L.norm_init(cfg, cfg.d_model, n),
            "moe": moe_init(cfg, ks[2], n),
        },
        "ln_f": L.norm_init(cfg, cfg.d_model),
    }


def forward(params: dict, cfg: ModelConfig, batch: dict):
    x = L.embed_tokens(params, cfg, batch["tokens"])
    b, s, _ = x.shape
    cos, sin = L.rope_for(cfg, T._positions(cfg, b, s))

    def body(carry, lp):
        h, aux = carry
        h = h + L.attn_train(lp["attn"], cfg,
                             L.norm_apply(lp["ln1"], cfg, h), cos, sin)
        y, a = moe_apply(lp["moe"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        return (h + y, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    x = L.norm_apply(params["ln_f"], cfg, x)
    # compute-dtype logits: see transformer.forward (§Perf log)
    logits = L.unembed(params, cfg, x)
    return logits, aux / cfg.num_layers


init_cache = T.init_cache


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict,
            last=None):
    x = L.embed_tokens(params, cfg, batch["tokens"])
    b, s, _ = x.shape
    cap = cache["k"].shape[2]
    cos, sin = L.rope_for(cfg, T._positions(cfg, b, s))

    def body(h, lp):
        y, kk, vv = L.attn_prefill(lp["attn"], cfg,
                                   L.norm_apply(lp["ln1"], cfg, h), cos, sin)
        h = h + y
        y, _ = moe_apply(lp["moe"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        h = h + y
        kk = kk[:, -cap:] if s >= cap else jnp.pad(
            kk, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
        vv = vv[:, -cap:] if s >= cap else jnp.pad(
            vv, ((0, 0), (0, cap - s), (0, 0), (0, 0)))
        return h, (kk, vv)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    xl = x[:, -1:] if last is None else jax.lax.dynamic_slice_in_dim(
        x, last, 1, axis=1)
    x = L.norm_apply(params["ln_f"], cfg, xl)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"k": ks, "v": vs}


def prefill_paged(params: dict, cfg: ModelConfig, pool_k: jnp.ndarray,
                  pool_v: jnp.ndarray, table: jnp.ndarray,
                  tokens: jnp.ndarray, start, *, block_size: int, last):
    """Continuation prefill of one chunk — the MoE twin of
    ``transformer.prefill_paged`` (expert FFN instead of the dense MLP).

    Caveat the dense twin does not have: capacity-based routing groups over
    the CHUNK length, so per-token expert outputs match a whole-prompt
    prefill exactly only while no token is capacity-dropped in either
    grouping (generous ``moe_capacity_factor``, as at smoke scale); routing
    itself is per-token and unaffected by chunking."""
    x = L.embed_tokens(params, cfg, tokens)
    b, c, _ = x.shape
    start = jnp.asarray(start, jnp.int32)
    cos, sin = L.rope_for(cfg, T._positions(cfg, b, c, offset=start))

    def body(h, xs):
        lp, pk, pv = xs
        y, k1, v1 = L.attn_prefill_paged(lp["attn"], cfg,
                                         L.norm_apply(lp["ln1"], cfg, h),
                                         cos, sin, pk, pv, table, start,
                                         block_size)
        h = h + y
        y2, _ = moe_apply(lp["moe"], cfg, L.norm_apply(lp["ln2"], cfg, h))
        h = h + y2
        return h, (k1, v1)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], pool_k, pool_v))
    xl = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)
    x = L.norm_apply(params["ln_f"], cfg, xl)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, ks[:, 0], vs[:, 0]


def decode_paged(params: dict, cfg: ModelConfig, pool_k: jnp.ndarray,
                 pool_v: jnp.ndarray, tables: jnp.ndarray,
                 tokens: jnp.ndarray, pos: jnp.ndarray, *, block_size: int):
    """One decode step against the paged KV pool — the MoE twin of
    ``transformer.decode_paged`` (expert FFN instead of the dense MLP).
    Returns (logits, new_k, new_v); no dense cache view is materialized."""
    x = L.embed_tokens(params, cfg, tokens)
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    window = T.paged_window(cfg, tables.shape[1] * block_size)
    cos, sin = L.rope_for(cfg, T._positions(cfg, b, 1, offset=pos[:, None]))

    def body(h, xs):
        lp, pk, pv = xs
        y, k1, v1 = L.attn_decode_paged(lp["attn"], cfg,
                                        L.norm_apply(lp["ln1"], cfg, h),
                                        cos, sin, pk, pv, tables, pos,
                                        block_size, window)
        h = h + y
        h = h + moe_decode_apply(lp["moe"], cfg,
                                 L.norm_apply(lp["ln2"], cfg, h))
        return h, (k1, v1)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], pool_k, pool_v))
    x = L.norm_apply(params["ln_f"], cfg, x)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, ks, vs


def decode(params: dict, cfg: ModelConfig, cache: dict, tokens: jnp.ndarray,
           pos: jnp.ndarray):
    x = L.embed_tokens(params, cfg, tokens)
    b = x.shape[0]
    cap = cache["k"].shape[2]
    offset, slot, valid = T._decode_pos_valid(cfg, pos, b, cap)
    cos, sin = L.rope_for(cfg, T._positions(cfg, b, 1, offset=offset))

    def body(h, xs):
        lp, kc, vc = xs
        y, kc, vc = L.attn_decode(lp["attn"], cfg,
                                  L.norm_apply(lp["ln1"], cfg, h),
                                  cos, sin, kc, vc, slot, valid)
        h = h + y
        h = h + moe_decode_apply(lp["moe"], cfg,
                                 L.norm_apply(lp["ln2"], cfg, h))
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = L.norm_apply(params["ln_f"], cfg, x)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"k": ks, "v": vs}
