"""Uniform model API over all architecture families.

    m = build_model(cfg)
    params             = m.init(cfg, key)
    logits, aux        = m.forward(params, cfg, batch)     # train (teacher forcing)
    cache              = m.init_cache(cfg, batch_size, capacity)
    logits, cache      = m.prefill(params, cfg, batch, cache)
    logits, cache      = m.decode(params, cfg, cache, tokens, pos)
    logits, k1, v1     = m.decode_paged(params, cfg, pool_k, pool_v, tables,
                                        tokens, pos, block_size=bs)  # serving
    logits, ks, vs     = m.prefill_paged(params, cfg, pool_k, pool_v, table,
                                         tokens, start, block_size=bs,
                                         last=n)  # serving chunked prefill

``batch`` is a dict: tokens (B, S) int32, plus family extras —
vision_embeds (B, P, d) for vlm, frames (B, enc_seq, d) for audio.
``aux`` is the MoE load-balance loss (0.0 elsewhere).
"""
from __future__ import annotations

import types

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, mamba2, moe, transformer


def _family(cfg: ModelConfig):
    return {
        "dense": transformer,
        "vlm": transformer,
        "moe": moe,
        "ssm": mamba2,
        "hybrid": hybrid,
        "audio": encdec,
    }[cfg.arch_type]


def build_model(cfg: ModelConfig) -> types.SimpleNamespace:
    fam = _family(cfg)

    def forward(params, cfg, batch):
        out = fam.forward(params, cfg, batch)
        if isinstance(out, tuple):
            return out
        return out, jnp.float32(0.0)

    return types.SimpleNamespace(
        init=fam.init,
        forward=forward,
        init_cache=fam.init_cache,
        prefill=fam.prefill,
        decode=fam.decode,
        # paged-pool entry points (serving) — transformer/moe only; other
        # families cache recurrent state and never page.  decode_paged is
        # the hot loop; prefill_paged is the chunk-continuation prefill
        # behind chunked prefill and prefix-shared admission
        decode_paged=getattr(fam, "decode_paged", None),
        prefill_paged=getattr(fam, "prefill_paged", None),
        family=fam,
    )


def init_params(cfg: ModelConfig, key):
    return build_model(cfg).init(cfg, key)


def init_cache(cfg: ModelConfig, batch: int, capacity: int):
    return build_model(cfg).init_cache(cfg, batch, capacity)
