"""Shared transformer building blocks (pure-JAX pytrees).

Params are plain dicts; per-layer params are stacked along a leading L axis
and consumed by ``jax.lax.scan``.  All blocks compute in ``cfg.dtype``
(bf16 by default) with fp32 accumulation inside the fused ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, width: int, layers: int | None = None) -> dict:
    shape = (width,) if layers is None else (layers, width)
    p = {"scale": jnp.ones(shape, cdtype(cfg))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros(shape, cdtype(cfg))
    return p


def norm_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm_type == "layernorm":
        x32 = x.astype(jnp.float32)
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    return ops.rmsnorm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# attention block (GQA + RoPE/M-RoPE + causal/SWA; used by dense/moe/vlm/
# hybrid-shared-block and whisper self/cross attention)
# ---------------------------------------------------------------------------

def attn_init(cfg: ModelConfig, key, layers: int | None = None) -> dict:
    h, kv, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    lead = () if layers is None else (layers,)
    sc_in = 1.0 / np.sqrt(d)
    sc_out = 1.0 / np.sqrt(h * hd)
    p = {
        "wq": _normal(ks[0], lead + (d, h * hd), sc_in, cdtype(cfg)),
        "wk": _normal(ks[1], lead + (d, kv * hd), sc_in, cdtype(cfg)),
        "wv": _normal(ks[2], lead + (d, kv * hd), sc_in, cdtype(cfg)),
        "wo": _normal(ks[3], lead + (h * hd, d), sc_out, cdtype(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(lead + (h * hd,), cdtype(cfg))
        p["bk"] = jnp.zeros(lead + (kv * hd,), cdtype(cfg))
        p["bv"] = jnp.zeros(lead + (kv * hd,), cdtype(cfg))
    return p


def _qkv(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return (q.reshape(b, s, h, hd), k.reshape(b, s, kv, hd),
            v.reshape(b, s, kv, hd))


def attn_project_out(p: dict, y: jnp.ndarray) -> jnp.ndarray:
    b, s, h, hd = y.shape
    return jnp.einsum("bsk,kd->bsd", y.reshape(b, s, h * hd), p["wo"])


def attn_train(p: dict, cfg: ModelConfig, x: jnp.ndarray, cos, sin,
               window: int | None = None, causal: bool = True) -> jnp.ndarray:
    """Full-sequence self-attention (training / prefill compute)."""
    q, k, v = _qkv(p, cfg, x)
    if cos is not None:
        q = ops.apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
        k = ops.apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])
    w = cfg.sliding_window if window is None else window
    y = ops.attention(q, k, v, causal=causal, window=w)
    return attn_project_out(p, y)


def attn_prefill(p: dict, cfg: ModelConfig, x: jnp.ndarray, cos, sin,
                 window: int | None = None):
    """Like attn_train but also returns (k, v) for cache insertion."""
    q, k, v = _qkv(p, cfg, x)
    if cos is not None:
        q = ops.apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
        k = ops.apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])
    w = cfg.sliding_window if window is None else window
    y = ops.attention(q, k, v, causal=True, window=w)
    return attn_project_out(p, y), k, v


def attn_decode(p: dict, cfg: ModelConfig, x1: jnp.ndarray, cos1, sin1,
                k_cache, v_cache, slot: jnp.ndarray, valid: jnp.ndarray):
    """One-token decode.  x1: (B, 1, d); k_cache/v_cache: (B, S, KV, hd);
    slot: () int32 — the cache slot to write (ring-buffered by the caller) —
    or (B,) int32 for per-sequence slots (continuous-batching serving, where
    every sequence sits at its own depth);
    valid: (B, S) bool — live cache slots AFTER insertion."""
    q, k, v = _qkv(p, cfg, x1)
    if cos1 is not None:
        q = ops.apply_rope(q, cos1[:, :, None, :], sin1[:, :, None, :])
        k = ops.apply_rope(k, cos1[:, :, None, :], sin1[:, :, None, :])
    if jnp.ndim(slot) == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    else:
        rows = jnp.arange(k_cache.shape[0])
        k_cache = k_cache.at[rows, slot].set(k[:, 0])
        v_cache = v_cache.at[rows, slot].set(v[:, 0])
    y = ops.decode_attention(q, k_cache, v_cache, valid)
    return attn_project_out(p, y), k_cache, v_cache


def attn_decode_paged(p: dict, cfg: ModelConfig, x1: jnp.ndarray, cos1, sin1,
                      pool_k, pool_v, tables, pos, block_size: int,
                      window: int):
    """One-token decode against the PAGED pool (continuous-batching serving).

    pool_k/pool_v: one layer's row pool (R, KV, hd) — read-only here; no
    dense per-slot cache view is ever built.  tables: (S, MB) int32 block
    table; pos: (S,) int32 cached rows per slot.  Returns the attention
    output plus this token's (k, v) rows (S, KV, hd) for the engine to
    scatter into the pool after the step."""
    q, k, v = _qkv(p, cfg, x1)
    if cos1 is not None:
        q = ops.apply_rope(q, cos1[:, :, None, :], sin1[:, :, None, :])
        k = ops.apply_rope(k, cos1[:, :, None, :], sin1[:, :, None, :])
    y = ops.paged_decode_attention(q, k[:, 0], v[:, 0], pool_k, pool_v,
                                   tables, pos, block_size=block_size,
                                   window=window)
    return attn_project_out(p, y), k[:, 0], v[:, 0]


def attn_prefill_paged(p: dict, cfg: ModelConfig, x: jnp.ndarray, cos, sin,
                       pool_k, pool_v, table, start, block_size: int,
                       window: int | None = None):
    """Continuation prefill of one CHUNK for ONE slot against the paged pool
    (chunked prefill / prefix-shared admission).  x: (1, C, d) chunk hidden
    states at global positions ``start + i``; pool_k/pool_v: (R, KV, hd) one
    layer's row pool (read-only here); table: (MB,) int32 the slot's block
    row; start: () int32 rows already resident.  Returns (out, k, v) like
    ``attn_prefill`` — the caller scatters k/v into the pool afterwards."""
    q, k, v = _qkv(p, cfg, x)
    if cos is not None:
        q = ops.apply_rope(q, cos[:, :, None, :], sin[:, :, None, :])
        k = ops.apply_rope(k, cos[:, :, None, :], sin[:, :, None, :])
    w = cfg.sliding_window if window is None else window
    y = ops.chunk_prefill_attention(q, k, v, pool_k, pool_v, table, start,
                                    block_size=block_size, window=w)
    return attn_project_out(p, y), k, v


def cross_attn_decode(p: dict, cfg: ModelConfig, x1: jnp.ndarray,
                      k_cache, v_cache):
    """Cross-attention decode against a static (encoder) cache."""
    b, _, _ = x1.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x1, p["wq"]).reshape(b, 1, h, hd)
    valid = jnp.ones(k_cache.shape[:2], bool)
    y = ops.decode_attention(q, k_cache, v_cache, valid)
    return attn_project_out(p, y)


def cross_attn_train(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                     enc_k, enc_v) -> jnp.ndarray:
    """Full-sequence cross attention (no mask — encoder is fully visible)."""
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"]).reshape(b, s, h, hd)
    y = ops.attention(q, enc_k, enc_v, causal=False, window=0)
    return attn_project_out(p, y)


def cross_kv(p: dict, cfg: ModelConfig, enc_out: jnp.ndarray):
    b, s, _ = enc_out.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dk->bsk", enc_out, p["wk"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,dk->bsk", enc_out, p["wv"]).reshape(b, s, kv, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, layers: int | None = None,
             d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    lead = () if layers is None else (layers,)
    ks = jax.random.split(key, 3)
    p = {
        "w_up": _normal(ks[1], lead + (d, f), 1 / np.sqrt(d), cdtype(cfg)),
        "w_down": _normal(ks[2], lead + (f, d), 1 / np.sqrt(f), cdtype(cfg)),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = _normal(ks[0], lead + (d, f), 1 / np.sqrt(d), cdtype(cfg))
    return p


def mlp_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if cfg.mlp_type == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        hidden = ops.swiglu(gate, up)
    else:
        hidden = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", hidden, p["w_down"])


# ---------------------------------------------------------------------------
# embeddings / positions
# ---------------------------------------------------------------------------

def embed_init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 2)
    p = {"embed": _normal(ks[0], (cfg.vocab_size, cfg.d_model), 1.0,
                          cdtype(cfg))}
    if not cfg.tie_embeddings:
        p["lm_head"] = _normal(
            ks[1], (cfg.d_model, cfg.vocab_size),
            1 / np.sqrt(cfg.d_model), cdtype(cfg))
    return p


def constrain_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Pin the leading (batch) dim of an activation to the data axes.

    Without this XLA's sharding propagation can settle on batch-REPLICATED
    activations (measured: qwen1.5-110b train kept the full global batch on
    every device — §Perf log); one constraint at the embedding anchors the
    whole layer stack."""
    from jax.sharding import PartitionSpec as P

    mesh = ops.ambient_mesh()
    if mesh is None:
        return x
    names = list(mesh.axis_names)
    sizes = (dict(zip(names, mesh.axis_sizes)) if hasattr(mesh, "axis_sizes")
             else {a: mesh.shape[a] for a in names})
    axes = tuple(a for a in ("pod", "data") if a in sizes)
    n = 1
    for a in axes:
        n *= sizes[a]
    if n > 1 and x.shape[0] % n == 0:
        spec = P(axes if len(axes) > 1 else axes[0],
                 *([None] * (x.ndim - 1)))
        return ops._maybe_constrain(x, spec)
    return x


def embed_tokens(p: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    return constrain_batch(jnp.take(p["embed"], tokens, axis=0))


def unembed(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


def sinusoid_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings.  positions: (...,) int32."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / (half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def rope_for(cfg: ModelConfig, positions: jnp.ndarray):
    """cos/sin for standard RoPE, or None for non-RoPE models."""
    if cfg.rope_theta <= 0:
        return None, None
    return ops.rope_tables(positions, cfg.head_dim, cfg.rope_theta)


def mrope_for(cfg: ModelConfig, positions3: jnp.ndarray):
    return ops.mrope_tables(positions3, cfg.head_dim, cfg.rope_theta,
                            cfg.mrope_sections)
