"""Mamba2 — SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill use the chunked SSD algorithm (quadratic within a chunk,
linear state recurrence across chunks — the TPU-friendly formulation: all
chunk-local work is MXU einsums, the cross-chunk recurrence is a short
``lax.scan``).  Decode is the O(1) recurrent update on the SSM state.

Deviation from the CUDA reference (recorded in DESIGN.md): the fused
``in_proj`` is split into separate z/x/B/C/dt projections so each output
dimension gets a clean SPMD sharding (heads over the "model" axis) instead of
slicing a fused sharded dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L


# ---------------------------------------------------------------------------
# block params
# ---------------------------------------------------------------------------

def block_init(cfg: ModelConfig, key, layers: int) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    g, ds, h, k = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv_kernel
    ks = jax.random.split(key, 8)
    dt = L.cdtype(cfg)
    lead = (layers,)
    sc = 1 / np.sqrt(d)
    p = {
        "ln": {"scale": jnp.ones(lead + (d,), dt)},
        "wz": L._normal(ks[0], lead + (d, di), sc, dt),
        "wx": L._normal(ks[1], lead + (d, di), sc, dt),
        "wB": L._normal(ks[2], lead + (d, g * ds), sc, dt),
        "wC": L._normal(ks[3], lead + (d, g * ds), sc, dt),
        "wdt": L._normal(ks[4], lead + (d, h), sc, jnp.float32),
        "dt_bias": jnp.broadcast_to(
            jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))), lead + (h,)
        ),
        "conv_wx": L._normal(ks[5], lead + (k, di), 1 / np.sqrt(k), dt),
        "conv_bx": jnp.zeros(lead + (di,), dt),
        "conv_wB": L._normal(ks[6], lead + (k, g * ds), 1 / np.sqrt(k), dt),
        "conv_bB": jnp.zeros(lead + (g * ds,), dt),
        "conv_wC": L._normal(ks[7], lead + (k, g * ds), 1 / np.sqrt(k), dt),
        "conv_bC": jnp.zeros(lead + (g * ds,), dt),
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)), lead + (h,)),
        "D": jnp.ones(lead + (h,), jnp.float32),
        "norm": {"scale": jnp.ones(lead + (di,), dt)},
        "out_proj": L._normal(
            jax.random.fold_in(key, 9), lead + (di, d), 1 / np.sqrt(di), dt),
    }
    return p


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------

def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """x: (B,S,D), w: (k,D), b: (D,) — depthwise causal conv + silu."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    y32 = y.astype(jnp.float32)
    return (y32 * jax.nn.sigmoid(y32)).astype(x.dtype)


def _conv_step(x1: jnp.ndarray, state: jnp.ndarray, w: jnp.ndarray,
               b: jnp.ndarray):
    """x1: (B,D); state: (B,k-1,D) last inputs.  Returns (y1, new_state)."""
    full = jnp.concatenate([state, x1[:, None]], axis=1)       # (B,k,D)
    y = jnp.einsum("bkd,kd->bd", full, w) + b
    y32 = y.astype(jnp.float32)
    return (y32 * jax.nn.sigmoid(y32)).astype(x1.dtype), full[:, 1:]


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., q) -> lower-triangular pairwise segment sums (..., q, q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(x, a, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD.

    x:  (B, S, H, P)  — dt already folded in (x * dt)
    a:  (B, S, H)     — log-decay per step (A * dt, negative)
    Bm: (B, S, G, N); Cm: (B, S, G, N) with H % G == 0.
    Returns y (B, S, H, P) and final state (B, H, P, N).
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    q = min(chunk, s)
    while s % q:
        q //= 2
    c = s // q
    hg = h // g

    xg = x.reshape(b, c, q, g, hg, p).astype(jnp.float32)       # (b,c,q,g,H,p)
    ag = a.reshape(b, c, q, g, hg).transpose(0, 3, 4, 1, 2)     # (b,g,H,c,q)
    Bc = Bm.reshape(b, c, q, g, n).astype(jnp.float32)
    Cc = Cm.reshape(b, c, q, g, n).astype(jnp.float32)
    a_cum = jnp.cumsum(ag, axis=-1)                             # (b,g,H,c,q)

    # --- intra-chunk (diagonal blocks): quadratic attention-like einsums ---
    Ldec = jnp.exp(_segsum(ag))                                 # (b,g,H,c,q,q)
    scores = jnp.einsum("bcqgn,bckgn->bgcqk", Cc, Bc)           # (b,g,c,q,k)
    y_diag = jnp.einsum("bgcqk,bgHcqk,bckgHp->bcqgHp", scores, Ldec, xg)

    # --- chunk states: what each chunk contributes to the carried state ---
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)             # (b,g,H,c,q)
    states = jnp.einsum("bckgn,bgHck,bckgHp->bcgHpn", Bc, decay_states, xg)

    # --- inter-chunk recurrence (short scan over c chunks) ---
    chunk_decay = jnp.exp(a_cum[..., -1])                       # (b,g,H,c)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    init_g = init_state.reshape(b, g, hg, p, n).astype(jnp.float32)

    def step(carry, xs):
        st, dec = xs                                # (b,g,H,p,n), (b,g,H)
        new = carry * dec[..., None, None] + st
        return new, carry                           # emit the PREVIOUS state

    final, prev_states = jax.lax.scan(
        step, init_g,
        (states.transpose(1, 0, 2, 3, 4, 5),        # (c,b,g,H,p,n)
         chunk_decay.transpose(3, 0, 1, 2)))        # (c,b,g,H)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)       # (b,c,g,H,p,n)

    # --- state -> output within each chunk ---
    state_decay = jnp.exp(a_cum)                                # (b,g,H,c,q)
    y_off = jnp.einsum("bcqgn,bcgHpn,bgHcq->bcqgHp",
                       Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final.reshape(b, h, p, n)


def ssd_step(x1, a1, B1, C1, state):
    """Recurrent decode update.

    x1: (B,H,P) (dt folded), a1: (B,H), B1/C1: (B,G,N), state: (B,H,P,N).
    """
    b, h, p = x1.shape
    g, n = B1.shape[1], B1.shape[2]
    hg = h // g
    Bh = jnp.repeat(B1, hg, axis=1)                             # (B,H,N)
    Ch = jnp.repeat(C1, hg, axis=1)
    new = (state * jnp.exp(a1)[..., None, None]
           + x1[..., None].astype(jnp.float32) * Bh[:, :, None, :].astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", new, Ch.astype(jnp.float32))
    return y, new


# ---------------------------------------------------------------------------
# block forward
# ---------------------------------------------------------------------------

def _proj_all(lp, cfg, xn):
    b, s, _ = xn.shape
    z = jnp.einsum("bsd,de->bse", xn, lp["wz"])
    xs = jnp.einsum("bsd,de->bse", xn, lp["wx"])
    Bm = jnp.einsum("bsd,de->bse", xn, lp["wB"])
    Cm = jnp.einsum("bsd,de->bse", xn, lp["wC"])
    dt = jnp.einsum("bsd,dh->bsh", xn.astype(jnp.float32), lp["wdt"])
    dt = jax.nn.softplus(dt + lp["dt_bias"])
    return z, xs, Bm, Cm, dt


def _finish(lp, cfg, y, z, x_in, dt):
    """gated norm + out projection.  y: (B,S,H,P) f32."""
    b, s, h, p = y.shape
    D = lp["D"]
    y = y + x_in.astype(jnp.float32) * dt[..., None] * D[None, None, :, None]
    y = y.reshape(b, s, h * p)
    z32 = z.astype(jnp.float32)
    y = y * (z32 * jax.nn.sigmoid(z32))
    y = L.ops.rmsnorm(y.astype(z.dtype), lp["norm"]["scale"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, lp["out_proj"])


def block_train(lp: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    xn = L.norm_apply(lp["ln"], cfg, x)
    b, s, _ = xn.shape
    h, p = cfg.ssm_nheads, cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    z, xs, Bm, Cm, dt = _proj_all(lp, cfg, xn)
    xs = _causal_conv(xs, lp["conv_wx"], lp["conv_bx"])
    Bm = _causal_conv(Bm, lp["conv_wB"], lp["conv_bB"])
    Cm = _causal_conv(Cm, lp["conv_wC"], lp["conv_bC"])
    xh = xs.reshape(b, s, h, p)
    A = -jnp.exp(lp["A_log"])                                   # (H,)
    a = A[None, None, :] * dt                                   # (B,S,H)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    y, _ = ssd_scan(xdt, a, Bm.reshape(b, s, g, n), Cm.reshape(b, s, g, n),
                    cfg.ssm_chunk)
    return x + _finish(lp, cfg, y, z, xh, dt).astype(x.dtype)


def block_prefill(lp: dict, cfg: ModelConfig, x: jnp.ndarray):
    """Returns (residual output, conv states dict, ssm state)."""
    xn = L.norm_apply(lp["ln"], cfg, x)
    b, s, _ = xn.shape
    h, p = cfg.ssm_nheads, cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    k = cfg.ssm_conv_kernel
    z, xs, Bm, Cm, dt = _proj_all(lp, cfg, xn)
    conv_state = {
        "x": _tail(xs, k - 1), "B": _tail(Bm, k - 1), "C": _tail(Cm, k - 1)}
    xs = _causal_conv(xs, lp["conv_wx"], lp["conv_bx"])
    Bm = _causal_conv(Bm, lp["conv_wB"], lp["conv_bB"])
    Cm = _causal_conv(Cm, lp["conv_wC"], lp["conv_bC"])
    xh = xs.reshape(b, s, h, p)
    A = -jnp.exp(lp["A_log"])
    a = A[None, None, :] * dt
    xdt = xh.astype(jnp.float32) * dt[..., None]
    y, final = ssd_scan(xdt, a, Bm.reshape(b, s, g, n),
                        Cm.reshape(b, s, g, n), cfg.ssm_chunk)
    out = x + _finish(lp, cfg, y, z, xh, dt).astype(x.dtype)
    return out, conv_state, final


def _tail(x: jnp.ndarray, m: int) -> jnp.ndarray:
    s = x.shape[1]
    if s >= m:
        return x[:, s - m:]
    return jnp.pad(x, ((0, 0), (m - s, 0), (0, 0)))


def block_decode(lp: dict, cfg: ModelConfig, x1: jnp.ndarray,
                 conv_state: dict, ssm_state: jnp.ndarray):
    """x1: (B, 1, d).  Returns (y1, conv_state, ssm_state)."""
    xn = L.norm_apply(lp["ln"], cfg, x1)
    b = xn.shape[0]
    h, p = cfg.ssm_nheads, cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    z, xs, Bm, Cm, dt = _proj_all(lp, cfg, xn)
    xs1, cx = _conv_step(xs[:, 0], conv_state["x"], lp["conv_wx"], lp["conv_bx"])
    Bm1, cb = _conv_step(Bm[:, 0], conv_state["B"], lp["conv_wB"], lp["conv_bB"])
    Cm1, cc = _conv_step(Cm[:, 0], conv_state["C"], lp["conv_wC"], lp["conv_bC"])
    xh = xs1.reshape(b, h, p)
    A = -jnp.exp(lp["A_log"])
    a1 = A[None, :] * dt[:, 0]                                  # (B,H)
    xdt = xh.astype(jnp.float32) * dt[:, 0, :, None]
    y, new_state = ssd_step(xdt, a1, Bm1.reshape(b, g, n),
                            Cm1.reshape(b, g, n), ssm_state)
    out = x1 + _finish(lp, cfg, y[:, None], z, xh[:, None],
                       dt).astype(x1.dtype)
    return out, {"x": cx, "B": cb, "C": cc}, new_state


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 3)
    return {
        **L.embed_init(cfg, ks[0]),
        "layers": block_init(cfg, ks[1], cfg.num_layers),
        "ln_f": L.norm_init(cfg, cfg.d_model),
    }


def forward(params: dict, cfg: ModelConfig, batch: dict):
    x = L.embed_tokens(params, cfg, batch["tokens"])

    def body(h, lp):
        return block_train(lp, cfg, h), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.norm_apply(params["ln_f"], cfg, x)
    # logits stay in the compute dtype: an f32 cast here would seed f32
    # cotangents through the WHOLE backward residual chain (§Perf log).
    return L.unembed(params, cfg, x)


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    del capacity  # SSM state is O(1) in sequence length
    n, b = cfg.num_layers, batch
    h, p, ds = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    g = cfg.ssm_ngroups
    k = cfg.ssm_conv_kernel
    dt = L.cdtype(cfg)
    return {
        "conv": {
            "x": jnp.zeros((n, b, k - 1, cfg.d_inner), dt),
            "B": jnp.zeros((n, b, k - 1, g * ds), dt),
            "C": jnp.zeros((n, b, k - 1, g * ds), dt),
        },
        "ssm": jnp.zeros((n, b, h, p, ds), jnp.float32),
    }


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    x = L.embed_tokens(params, cfg, batch["tokens"])

    def body(h, lp):
        out, conv, ssm = block_prefill(lp, cfg, h)
        return out, (conv, ssm)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (conv, ssm) = jax.lax.scan(body, x, params["layers"])
    x = L.norm_apply(params["ln_f"], cfg, x[:, -1:])
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"conv": conv, "ssm": ssm}


def decode(params: dict, cfg: ModelConfig, cache: dict, tokens: jnp.ndarray,
           pos: jnp.ndarray):
    del pos  # SSM decode is position-free
    x = L.embed_tokens(params, cfg, tokens)

    def body(h, xs):
        lp, conv, ssm = xs
        out, conv, ssm = block_decode(lp, cfg, h, conv, ssm)
        return out, (conv, ssm)

    x, (conv, ssm) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = L.norm_apply(params["ln_f"], cfg, x)
    logits = L.unembed(params, cfg, x)[:, 0].astype(jnp.float32)
    return logits, {"conv": conv, "ssm": ssm}
