"""Sharding rules: param-path -> PartitionSpec, per stage.

Two stages mirror the paper's resharding flow:

  * ``train`` (update stage)   — FSDP over "data" + TP/EP over "model";
    optimizer moments inherit the param spec (ZeRO is subsumed by FSDP).
  * ``gen`` (generation stage) — selectable layout:
      - "2d"  : same 2-D layout as train (weight-gathered decode; baseline)
      - "tp"  : TP over "model" only, replicated over "data" (no per-step
                weight allgather — for models that fit HBM)

The pair (train, gen) layouts being DIFFERENT is exactly what creates the
paper's resharding flow; ``core/resharding.py`` moves weights between them.

Rules are name-based over the param pytree paths; stacked (scanned) layers
are detected by rank (base rank + 1 leading layer axis).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def data_axes(mesh) -> tuple:
    """Batch-sharding axes: ("pod","data") on the multi-pod mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _mdl(mesh) -> int:
    return mesh.shape["model"]


# base (unstacked) dim specs per leaf name; "D"=fsdp axis, "M"=model axis.
# Resolved to mesh axes per stage.
_TABLE = {
    # embeddings
    "embed": ("M", "D"),
    "lm_head": ("D", "M"),
    # attention
    "wq": ("D", "M"), "wk": ("D", "M"), "wv": ("D", "M"),
    "bq": ("M",), "bk": ("M",), "bv": ("M",),
    "wo": ("M", "D"),
    # dense mlp
    "w_gate": ("D", "M"), "w_up": ("D", "M"), "w_down": ("M", "D"),
    # norms
    "scale": (None,), "bias": (None,),
    # mamba2
    "wz": ("D", "M"), "wx": ("D", "M"),
    "wB": ("D", None), "wC": ("D", None), "wdt": ("D", None),
    "dt_bias": (None,), "A_log": (None,), "D": (None,),
    "conv_wx": (None, "M"), "conv_bx": ("M",),
    "conv_wB": (None, None), "conv_bB": (None,),
    "conv_wC": (None, None), "conv_bC": (None,),
    "out_proj": ("M", "D"),
}

# MoE expert tables (under a "moe" parent). EP when E divides the model axis.
# FSDP ("D") must shard the NON-contracting dim of each expert matmul: putting
# it on the contraction dim forces an all-reduce of every expert output over
# the data axis (measured 17.9 TB/device on llama4 train_4k — §Perf log).
_TABLE_MOE_EP = {
    "router": ("D", None),
    "w_gate": ("M", None, "D"), "w_up": ("M", None, "D"),
    "w_down": ("M", "D", None),
}
_TABLE_MOE_TP = {
    "router": ("D", None),
    "w_gate": (None, "D", "M"), "w_up": (None, "D", "M"),
    "w_down": (None, "M", "D"),
}


def _resolve(dims, stage: str, mesh) -> P:
    """Map the symbolic ("D"/"M"/None) dims to mesh axes for a stage."""
    out = []
    for d in dims:
        if d == "M":
            out.append("model")
        elif d == "D":
            if stage == "train":
                out.append(data_axes(mesh) if len(data_axes(mesh)) > 1
                           else "data")
            else:  # gen "2d" keeps fsdp; "tp" replicates over data
                out.append("data" if stage == "gen2d" else None)
        else:
            out.append(None)
    return P(*out)


def _leaf_spec(path, leaf, cfg: ModelConfig, stage: str, mesh) -> P:
    keys = [getattr(k, "key", str(k)) for k in path]
    name = keys[-1]
    parent = keys[-2] if len(keys) > 1 else ""
    in_moe = "moe" in keys
    if in_moe:
        ep = cfg.num_experts % _mdl(mesh) == 0
        table = _TABLE_MOE_EP if ep else _TABLE_MOE_TP
        dims = table.get(name)
    else:
        dims = _TABLE.get(name)
        if dims is None and parent in ("norm", "ln", "ln1", "ln2", "lnx",
                                       "ln_f", "enc_ln"):
            dims = (None,)
    if dims is None:
        dims = (None,) * leaf.ndim  # replicate unknown leaves
    ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
    extra = ndim - len(dims)
    if extra > 0:        # stacked layers / group dims -> leading None axes
        dims = (None,) * extra + tuple(dims)
    elif extra < 0:
        dims = tuple(dims)[-ndim:] if ndim else ()
    spec = _resolve(dims, stage, mesh)
    # never shard a dim the mesh axis cannot divide AND that is tiny
    fixed = []
    shape = getattr(leaf, "shape", ())
    for i, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        size = mesh.shape[ax] if isinstance(ax, str) else 1
        if isinstance(ax, tuple):
            size = 1
            for a in ax:
                size *= mesh.shape[a]
        if shape and shape[i] % size != 0:
            fixed.append(None)   # jit arg shardings must divide evenly
        else:
            fixed.append(ax)
    return P(*fixed)


def param_specs(cfg: ModelConfig, params, mesh, stage: str = "train",
                gen_mode: str = "2d"):
    """Tree of PartitionSpec matching ``params`` (arrays or ShapeDtypeStruct).

    stage: "train" | "gen"; gen_mode: "2d" | "tp".
    """
    tag = "train" if stage == "train" else ("gen2d" if gen_mode == "2d"
                                            else "gen")
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, cfg, tag, mesh), params)


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_partition(mesh, global_batch: int) -> P | None:
    """Spec for the leading batch dim (None when batch < axis size)."""
    axes = data_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if global_batch % size == 0:
        return axes if len(axes) > 1 else axes[0]
    if global_batch % mesh.shape["data"] == 0:
        return "data"
    return None


def cache_specs(cfg: ModelConfig, cache, mesh):
    """Specs for a decode cache pytree (leaves have a leading layer axis and
    a batch axis second)."""
    def spec(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        b = leaf.shape[1]
        bax = batch_partition(mesh, b) if b > 1 else None
        mdl = mesh.shape["model"]
        if name in ("k", "v", "attn_k", "attn_v", "xk", "xv"):
            # (L, B, S, KV, hd): shard kv heads when they divide the model
            # axis; otherwise shard head_dim (contraction-sharded attention,
            # small all-reduce) rather than replicating the whole cache.
            if leaf.shape[3] % mdl == 0:
                return P(None, bax, None, "model", None)
            if leaf.shape[4] % mdl == 0:
                return P(None, bax, None, None, "model")
            return P(None, bax, None, None, None)
        if name == "ssm":
            # (L, B, H, P, N)
            hax = "model" if leaf.shape[2] % mdl == 0 else None
            return P(None, bax, hax, None, None)
        if name in ("x", "B", "C"):
            # conv states (L, B, k-1, D)
            dax = "model" if leaf.shape[3] % mdl == 0 else None
            return P(None, bax, None, dax)
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(spec, cache)
