"""Pipeline parallelism (paper Table 2 "PP") — GPipe schedule over a "pipe"
mesh axis, expressed with shard_map + collective_permute.

The stacked layer parameters (L, ...) are sharded on the layer axis across P
pipe stages (L/P layers per stage).  The global batch is split into M
microbatches; for M + P - 1 steps each stage runs its local layers on the
microbatch it holds and ppermutes the activations to the next stage.  Stage 0
injects fresh microbatches, stage P-1 accumulates outputs.  Bubble fraction
is the classic (P-1)/(M+P-1); jax autodiff differentiates straight through
the schedule (the transpose of ppermute is the reverse permute), giving the
1F1B-equivalent memory profile when each step is rematerialized.

This is an optional composition: the dense families run it through
``pipeline_forward`` when the mesh carries a "pipe" axis.  It composes with
the data/model sharding of everything else (shard_map is over the pipe axis
only; inner ops remain jit-sharded over the other axes).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(layer_fn, stacked_params, x, mesh, *,
                     microbatches: int, axis: str = "pipe", consts=()):
    """Run ``layer_fn`` (params_slice, x, *consts) -> x over L stacked layers
    as a P-stage pipeline.

    stacked_params: pytree with leading layer axis L (L % P == 0).
    x: (B, ...) global batch (B % microbatches == 0).
    consts: extra replicated arrays every stage needs (e.g. RoPE tables) —
    positions are batch-invariant so one copy serves all microbatches.
    Returns (B, ...) outputs — numerically identical to the sequential scan.
    Call under ``jax.jit`` (shard_map autodiff needs it).
    """
    nstages = mesh.shape[axis]
    b = x.shape[0]
    m = microbatches
    assert b % m == 0, (b, m)
    mb = b // m
    xs = x.reshape((m, mb) + x.shape[1:])

    def stage_fn(params_blk, xs_blk, *consts_blk):
        # params_blk: (L/P, ...) this stage's layers; xs_blk: (M, mb, ...)
        # replicated input microbatches (only stage 0 reads them).
        stage = jax.lax.axis_index(axis)
        state = jnp.zeros_like(xs_blk[0])
        acc = jnp.zeros_like(xs_blk)

        def run_local(h):
            def body(carry, lp):
                return layer_fn(lp, carry, *consts_blk), None
            out, _ = jax.lax.scan(body, h, params_blk)
            return out

        perm = [(i, i + 1) for i in range(nstages - 1)]
        for t in range(m + nstages - 1):
            inject = xs_blk[min(t, m - 1)]
            h = jnp.where(stage == 0, inject, state)
            out = jax.checkpoint(run_local)(h)
            # stage P-1 finished microbatch t-(P-1) at step t
            j = t - (nstages - 1)
            if j >= 0:
                keep = (stage == nstages - 1)
                acc = acc.at[j].add(jnp.where(keep, out, 0.0))
            state = jax.lax.ppermute(out, axis, perm)
        # deliver the accumulated outputs from the last stage to everyone
        return jax.lax.psum(acc, axis)

    pspec = jax.tree.map(lambda _: P(axis), stacked_params)
    cspecs = tuple(jax.tree.map(lambda _: P(), c) for c in consts)
    fn = shard_map(stage_fn, mesh=mesh,
                   in_specs=(pspec, P()) + cspecs, out_specs=P(),
                   check_rep=False)
    out = fn(stacked_params, xs, *consts)
    return out.reshape((b,) + x.shape[1:])


def sequential_forward(layer_fn, stacked_params, x):
    """Reference: the plain layer scan."""
    def body(carry, lp):
        return layer_fn(lp, carry), None
    out, _ = jax.lax.scan(body, x, stacked_params)
    return out
