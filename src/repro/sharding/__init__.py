from repro.sharding.rules import (  # noqa: F401
    batch_partition,
    cache_specs,
    data_axes,
    param_specs,
    to_named,
)
