"""Grouped matmul (GMM) Pallas TPU kernel — the MoE expert-FFN hot spot.

x (T, d) holds tokens sorted by expert with every group boundary aligned to
``tile_t`` (the caller pads each group); w (E, d, f).  The expert id of each
row tile is data-dependent, so it is passed through scalar prefetch
(PrefetchScalarGridSpec) and consumed by the weight BlockSpec index_map —
exactly the megablocks-on-TPU adaptation: contiguous MXU tiles instead of
GPU gather-scatter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Deployment envelope for the VMEM budget check (tools/analyze kernel-shapes):
# widest MoE d_model in the config zoo is 5120 (llama4-scout class).
# Worst case: x 2.5 MiB + w 10 MiB + out 0.25 MiB per program.
VMEM_BOUNDS = {"d": 5120}


def _gmm_kernel(tile_gid_ref, x_ref, w_ref, o_ref):
    del tile_gid_ref  # consumed by the index_map
    x = x_ref[...].astype(jnp.float32)          # (tile_t, d)
    w = w_ref[0].astype(jnp.float32)            # (d, block_f)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_t", "block_f", "interpret"))
def gmm(x: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray, *,
        tile_t: int = 128, block_f: int = 512,
        interpret: bool = False) -> jnp.ndarray:
    """x: (T, d) group-sorted, tile-aligned; w: (E, d, f); group_sizes: (E,)."""
    t, d = x.shape
    e, _, f = w.shape
    assert t % tile_t == 0, (t, tile_t)
    block_f = min(block_f, f)
    while f % block_f:
        block_f //= 2
    block_f = max(block_f, 1)
    nt = t // tile_t

    # expert id per row tile, from the (traced) group sizes
    offsets = jnp.cumsum(group_sizes)                      # (E,)
    tile_start = jnp.arange(nt, dtype=jnp.int32) * tile_t
    tile_gid = jnp.clip(
        jnp.searchsorted(offsets, tile_start, side="right"), 0, e - 1
    ).astype(jnp.int32)  # trailing padding tiles compute with the last
    # expert's weights; their rows are never read back

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, f // block_f),
        in_specs=[
            pl.BlockSpec((tile_t, d), lambda i, j, gid: (i, 0)),
            pl.BlockSpec((1, d, block_f), lambda i, j, gid: (gid[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_t, block_f), lambda i, j, gid: (i, j)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret,
    )(tile_gid, x, w)


def pad_groups(x: jnp.ndarray, gid: jnp.ndarray, num_groups: int,
               tile_t: int = 128):
    """Helper: sort rows of ``x`` by group id and pad every group to a
    ``tile_t`` multiple.  Returns (x_sorted_padded, padded_group_sizes,
    inverse_gather_idx, valid_mask) so callers can un-permute the output."""
    t = x.shape[0]
    order = jnp.argsort(gid, stable=True)
    sizes = jnp.bincount(gid, length=num_groups)
    padded = ((sizes + tile_t - 1) // tile_t) * tile_t
    pad_total = int(num_groups * tile_t)  # worst-case extra rows (static)
    out_rows = t + pad_total
    starts = jnp.concatenate([jnp.zeros((1,), sizes.dtype),
                              jnp.cumsum(padded)[:-1]])
    # destination row of each (sorted) source row
    src_group = jnp.sort(gid, stable=True)
    within = jnp.arange(t) - jnp.take(jnp.concatenate(
        [jnp.zeros((1,), sizes.dtype), jnp.cumsum(sizes)[:-1]]), src_group)
    dest = jnp.take(starts, src_group) + within
    xs = jnp.zeros((out_rows, x.shape[1]), x.dtype).at[dest].set(x[order])
    return xs, padded, order, dest
