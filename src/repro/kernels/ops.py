"""Public fused-op API.

Models call these; the implementation dispatches to a Pallas TPU kernel when
running on TPU (or when REPRO_PALLAS=interpret forces interpret-mode), and to
a jnp implementation otherwise.  The jnp attention path is NOT the naive
oracle: it is a chunked online-softmax implementation with a custom VJP
(flash semantics), so the compiled HLO of the CPU dry-run has the same
asymptotic memory behaviour the TPU kernel has — the roofline analysis stays
honest.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

_FORCE_INTERPRET = os.environ.get("REPRO_PALLAS", "") == "interpret"


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu" or _FORCE_INTERPRET


# ---------------------------------------------------------------------------
# elementwise fusions
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    if _use_pallas() and x.ndim >= 2:
        from repro.kernels import rmsnorm as _k

        shape = x.shape
        out = _k.rmsnorm(x.reshape(-1, shape[-1]), w, eps=eps,
                         interpret=not jax.default_backend() == "tpu")
        return out.reshape(shape)
    return ref.rmsnorm(x, w, eps)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    if _use_pallas() and gate.ndim >= 2:
        from repro.kernels import swiglu as _k

        shape = gate.shape
        out = _k.swiglu(gate.reshape(-1, shape[-1]), up.reshape(-1, shape[-1]),
                        interpret=not jax.default_backend() == "tpu")
        return out.reshape(shape)
    return ref.swiglu(gate, up)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d), cos/sin broadcastable (..., d//2)."""
    if _use_pallas() and x.ndim == 4:
        from repro.kernels import rope as _k

        c, s = cos, sin
        if c.ndim == 4:            # callers pass a broadcast head axis
            c, s = c[:, :, 0], s[:, :, 0]
        return _k.apply_rope(x, c, s,
                             interpret=not jax.default_backend() == "tpu")
    return ref.rope(x, cos, sin)


def rope_tables(positions: jnp.ndarray, head_dim: int, theta: float):
    """cos/sin tables for rotate-half RoPE.  positions: (...,) int32.

    Returns cos, sin of shape positions.shape + (head_dim//2,).
    """
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_tables(positions: jnp.ndarray, head_dim: int, theta: float,
                 sections: tuple):
    """M-RoPE (qwen2-vl): positions (3, ...) for (t, h, w); the half-dim is
    split into ``sections`` (summing to head_dim//2), each section rotated by
    its own position stream."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (3, ..., half)
    idx = np.concatenate(
        [np.full((s,), i) for i, s in enumerate(sections)]
    )  # (half,) which position stream each channel uses
    onehot = jax.nn.one_hot(jnp.asarray(idx), 3, dtype=jnp.float32)  # (half, 3)
    ang = jnp.einsum("s...h,hs->...h", ang, onehot)
    return jnp.cos(ang), jnp.sin(ang)


# ---------------------------------------------------------------------------
# attention — flash semantics
# ---------------------------------------------------------------------------

_DEF_BLOCK = int(os.environ.get("REPRO_ATTN_BLOCK", "512"))
# jnp-path flash block size trade-off: the (acc, m, l) carry is re-read and
# re-written every kv block, so HBM carry traffic ∝ nb = Sk/block, while the
# per-block score tile traffic is ~constant in nb.  Larger blocks cut carry
# traffic linearly until the score tile dominates (§Perf log).  The Pallas
# TPU kernel keeps the carry in VMEM and has no such trade-off.


def _pick_block(s: int, target: int = 0) -> int:
    target = target or _DEF_BLOCK
    if s <= target:
        return s
    b = target
    while s % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal: bool, window: int, scale: float):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, scale)
    return out


def _flash_fwd_impl(q, k, v, causal, window, scale, q_pos=None):
    """Chunked online-softmax forward.  q:(B,Sq,H,d) k,v:(B,Sk,KV,d).

    ``q_pos`` ((Sq,) int32, optional) gives the queries' GLOBAL positions
    for the causal/window masks; the default keeps the standard convention
    (q rows are the last Sq of the Sk context).  Chunked prefill passes the
    chunk's absolute offsets — extra keys this masks out contribute exact
    zeros to every row's reductions, so a chunk's rows stay bitwise equal
    to a whole-prompt prefill whenever both contexts fit one kv block
    (``_pick_block``) AND both Sk are powers of two: XLA reduces a pow2 key
    length with the same real-element grouping at any pow2 size, but a
    non-pow2 Sk regroups the reduction value-dependently and breaks row
    bitwise-equality once a row attends past the regroup boundary (which
    is why ``chunk_prefill_attention`` pow2-pads its capacity window).
    Beyond one kv block the online-softmax rescan order differs and
    equality degrades to allclose."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    blk = _pick_block(sk)
    nb = sk // blk
    qg = (q.reshape(b, sq, kv, g, d) * scale).astype(jnp.float32)
    if q_pos is None:
        q_pos = jnp.arange(sq) + (sk - sq)

    kb = k.reshape(b, nb, blk, kv, d).swapaxes(0, 1).astype(jnp.float32)
    vb = v.reshape(b, nb, blk, kv, d).swapaxes(0, 1).astype(jnp.float32)

    def step(carry, xs):
        acc, m, l = carry
        kblk, vblk, i = xs
        k_pos = i * blk + jnp.arange(blk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk)
        mask = jnp.ones((sq, blk), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vblk)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kv, g, sq, d), jnp.float32)
    m0 = jnp.full((b, kv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (kb, vb, jnp.arange(nb))
    )
    out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    lse = (m + jnp.log(l))  # (B, KV, G, Sq)
    return out.astype(q.dtype), lse


def _flash_fwd(q, k, v, causal, window, scale):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, scale)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, scale, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    blk = _pick_block(sk)
    nb = sk // blk
    qg = q.reshape(b, sq, kv, g, d).astype(jnp.float32)
    dog = dout.reshape(b, sq, kv, g, d).astype(jnp.float32)
    og = out.reshape(b, sq, kv, g, d).astype(jnp.float32)
    delta = jnp.sum(dog * og, axis=-1).transpose(0, 2, 3, 1)  # (B,KV,G,Sq)
    q_pos = jnp.arange(sq) + (sk - sq)
    kb = k.reshape(b, nb, blk, kv, d).swapaxes(0, 1).astype(jnp.float32)
    vb = v.reshape(b, nb, blk, kv, d).swapaxes(0, 1).astype(jnp.float32)

    def step(dq, xs):
        kblk, vblk, i = xs
        k_pos = i * blk + jnp.arange(blk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk) * scale
        mask = jnp.ones((sq, blk), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        p = jnp.where(mask[None, None, None], jnp.exp(s - lse[..., None]), 0.0)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dog, vblk)
        ds = p * (dp - delta[..., None]) * scale
        dv_blk = jnp.einsum("bkgqs,bqkgd->bskd", p, dog)
        dk_blk = jnp.einsum("bkgqs,bqkgd->bskd", ds, qg)
        dq = dq + jnp.einsum("bkgqs,bskd->bqkgd", ds, kblk)
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, sq, kv, g, d), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(step, dq0, (kb, vb, jnp.arange(nb)))
    dk = dk_b.swapaxes(0, 1).reshape(b, sk, kv, d).astype(k.dtype)
    dv = dv_b.swapaxes(0, 1).reshape(b, sk, kv, d).astype(v.dtype)
    return dq.reshape(b, sq, h, d).astype(q.dtype), dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def _attention_batch_spec(b: int, h: int, sq: int = 0):
    """When the head count cannot divide the "model" axis, GQA attention
    cannot be head-sharded — XLA then contraction-shards the score einsums
    and all-reduces score-sized tensors every block (measured 16.5 TB/device
    on llama4 train_4k — §Perf log).  Two escapes, in preference order:

    1. batch divides the WHOLE mesh -> shard attention purely over batch
       (fully local, collectives only at entry/exit);
    2. otherwise, Ulysses-style sequence parallelism for prefill: shard the
       q SEQUENCE over "model" (k/v stay model-replicated, which for GQA is
       cheap) — per-device score compute drops by the model-axis size.

    Returns (q_spec, kv_spec) or None."""
    from jax.sharding import PartitionSpec as P

    mesh = ambient_mesh()
    if mesh is None:
        return None
    names = list(mesh.axis_names)
    sizes = (dict(zip(names, mesh.axis_sizes)) if hasattr(mesh, "axis_sizes")
             else {a: mesh.shape[a] for a in names})
    mdl = sizes.get("model", 1)
    if mdl <= 1 or h % mdl == 0:
        return None                       # head sharding works; leave to XLA
    axes = tuple(a for a in ("pod", "data", "model") if a in sizes)
    total = 1
    for a in axes:
        total *= sizes[a]
    if total > 1 and b % total == 0:
        spec = P(axes, None, None, None)
        return spec, spec
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    ndp = 1
    for a in dp:
        ndp *= sizes[a]
    bax = (dp if len(dp) > 1 else dp[0]) if ndp > 1 and b % ndp == 0 else None
    if sq > 1 and sq % mdl == 0:
        return (P(bax, "model", None, None), P(bax, None, None, None))
    return None


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale: float | None = None) -> jnp.ndarray:
    """GQA attention with flash semantics (chunked, O(S) memory, recompute
    backward).  q: (B,Sq,H,d); k,v: (B,Sk,KV,d)."""
    scale = scale if scale is not None else 1.0 / float(np.sqrt(q.shape[-1]))
    spec = _attention_batch_spec(q.shape[0], q.shape[2], q.shape[1])
    if spec is not None:
        qs, kvs = spec
        q = _maybe_constrain(q, qs)
        k = _maybe_constrain(k, kvs)
        v = _maybe_constrain(v, kvs)
    if _use_pallas():
        from repro.kernels import flash_attention as _k

        out = _k.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=not jax.default_backend() == "tpu")
    else:
        out = _flash(q, k, v, causal, window, scale)
    if spec is not None:
        # re-anchor: the Ulysses q-sequence sharding must NOT leak past the
        # attention — downstream MoE layers need the "model" axis for EP
        # (leaked S-sharding measured: full-expert f32 all-gathers on llama4
        # multi-pod prefill — §Perf log)
        out = _maybe_constrain(out, spec[1])
    return out


def ambient_mesh():
    """The mesh active at trace time: the new-style abstract mesh, or the
    legacy ``with mesh:`` thread-resources mesh.  None when single-device."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib

        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and pm.axis_names:
            return pm
    except Exception:
        pass
    return None


def _maybe_constrain(x, spec):
    """with_sharding_constraint when an ambient mesh provides the axes;
    no-op otherwise (single-device tests / examples)."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    flat = []
    for ax in spec:
        flat.extend(ax if isinstance(ax, tuple) else [ax])
    if any(ax is not None and ax not in mesh.axis_names for ax in flat):
        return x
    try:
        from jax.sharding import AbstractMesh, NamedSharding

        if isinstance(mesh, AbstractMesh):
            return jax.lax.with_sharding_constraint(x, spec)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:
        return x


def decode_attention(q, k_cache, v_cache, valid_mask, *,
                     scale: float | None = None) -> jnp.ndarray:
    """One-token attention against a (possibly ring-buffered) KV cache.

    q: (B, 1, H, d); k_cache/v_cache: (B, S, KV, d);
    valid_mask: (B, S) bool — True for live cache slots.
    Memory-bound; a plain einsum is roofline-optimal here.

    Sharding: when KV heads cannot divide the "model" axis the cache is
    head_dim-sharded (see sharding/rules.py); we pin q to the same layout so
    the contraction is local and only the (tiny) score partial-sums are
    all-reduced — instead of XLA re-gathering the whole cache per step.
    """
    from jax.sharding import PartitionSpec as P

    b, _, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    g = h // kv
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    # keep the cache in its storage dtype (bf16) and accumulate in f32 via
    # preferred_element_type — upcasting the cache would materialize (and,
    # under SPMD, re-gather) a full-precision copy of the whole cache.
    qg = (q.reshape(b, kv, g, d) * scale).astype(k_cache.dtype)
    mesh = ambient_mesh()
    mdl = dict(zip(mesh.axis_names,
                   getattr(mesh, "axis_sizes", None)
                   or [mesh.shape[a] for a in mesh.axis_names])
               ).get("model", 1) if mesh is not None else 1
    if mdl > 1 and kv % mdl and d % mdl == 0:
        # hd-sharded-cache regime (see sharding/rules.py)
        qg = _maybe_constrain(qg, P(None, None, None, "model"))
    sc = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                    preferred_element_type=jnp.float32)
    sc = jnp.where(valid_mask[:, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, d).astype(q.dtype)


def chunk_prefill_attention(q, k_new, v_new, pool_k, pool_v, table, start, *,
                            block_size: int, window: int = 0,
                            scale: float | None = None) -> jnp.ndarray:
    """Prefill-CONTINUATION attention for ONE slot over the paged pool —
    the compute behind chunked prefill and prefix-shared admission.

    q, k_new, v_new: (1, C, H|KV, d) — the chunk's fresh projections, global
    positions ``start + i`` (pad rows allowed past the real tail; they are
    causally invisible to real rows and their outputs are discarded);
    pool_k/pool_v: (R, KV, d) one layer's row pool; table: (MB,) int32 the
    slot's block-table row; start: () int32 rows already resident (shared
    prefix blocks and/or earlier chunks).

    Gathers the slot's capacity window (static MB*block_size rows — unlike
    decode this is NOT the hot loop; admission cost amortizes over the whole
    sequence), substitutes the chunk's fresh KV at its own rows, and runs
    the SAME chunked online-softmax forward full prefill uses
    (``_flash_fwd_impl``) with explicit global q positions.  Keys at
    logical positions > q_pos (stale rows, null-block rows, chunk pads) are
    causally masked and contribute exact zeros, which is what keeps a
    chunk's rows bitwise equal to the whole-prompt prefill on the jnp path
    (see ``_flash_fwd_impl``; the TPU whole-prefill path runs the Pallas
    flash kernel instead, where the contract is allclose, not bitwise).
    """
    scale = scale if scale is not None else 1.0 / float(np.sqrt(q.shape[-1]))
    c = q.shape[1]
    bs = block_size
    flat = (table[:, None] * bs + jnp.arange(bs)[None, :]).reshape(-1)
    # pad the window to the next POWER OF TWO (extra rows read the pool's
    # last null-block row; their positions exceed every real q_pos, so the
    # causal mask kills them).  The whole-prompt path always runs flash at
    # a pow2 key length (admission buckets), and pow2 lengths reduce with
    # identical real-element grouping — appended masked keys contribute
    # exact zeros.  A NON-pow2 capacity window (e.g. 48 rows) makes the
    # backend regroup the reduction value-dependently, which broke the
    # chunk==dense bit contract once a row attended past the regroup
    # boundary; the pad closes that hole.
    w = flat.shape[0]
    p2 = 1
    while p2 < w:
        p2 *= 2
    if p2 != w:
        flat = jnp.concatenate(
            [flat, jnp.full((p2 - w,), pool_k.shape[0] - 1, flat.dtype)])
    kw = pool_k[flat]                       # (pow2 >= MB*bs, KV, d)
    vw = pool_v[flat]
    idx = start + jnp.arange(c)
    # pad rows past the window clamp onto nothing ("drop"): they are masked
    # for every real query anyway
    kw = kw.at[idx].set(k_new[0], mode="drop")
    vw = vw.at[idx].set(v_new[0], mode="drop")
    out, _ = _flash_fwd_impl(q, kw[None], vw[None], True, window, scale,
                             q_pos=idx)
    return out


def paged_decode_attention(q, k_new, v_new, pool_k, pool_v, tables, pos, *,
                           block_size: int, window: int = 0,
                           scale: float | None = None) -> jnp.ndarray:
    """One-token attention straight off the paged KV pool — the serving hot
    loop's attention (no dense per-slot gather is ever materialized).

    q: (S, 1, H, d) decode queries; k_new/v_new: (S, KV, d) the in-flight
    token's KV (scattered into the pool by the caller AFTER this);
    pool_k/pool_v: (R, KV, d) one layer's row pool; tables: (S, MB) int32;
    pos: (S,) int32 cached rows per slot.

    Dispatch: flash-decoding Pallas kernel on TPU (or REPRO_PALLAS=interpret),
    else the chunked two-pass jnp reference — which is BITWISE equal to
    ``decode_attention`` over the dense-gathered view, preserving the serving
    engine's bit-compatibility with the synchronized rollout engine.
    """
    if _use_pallas():
        from repro.kernels import paged_attention as _k

        out = _k.paged_decode_attention(
            q[None, :, 0], k_new[None], v_new[None], pool_k[None],
            pool_v[None], tables, pos, block_size=block_size, window=window,
            scale=scale, interpret=not jax.default_backend() == "tpu")
        return out[0][:, None]
    return ref.paged_decode_attention(q, k_new, v_new, pool_k, pool_v, tables,
                                      pos, block_size=block_size,
                                      window=window, scale=scale)


# ---------------------------------------------------------------------------
# grouped matmul (MoE)
# ---------------------------------------------------------------------------

def gmm(x: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray,
        tile_t: int = 128) -> jnp.ndarray:
    """Grouped matmul: x (T,d) sorted by group, w (E,d,f), group_sizes (E,).

    TPU path: Pallas kernel with MXU-aligned tiles (caller must align group
    boundaries to ``tile_t``).  CPU path: one-hot einsum (dense over E — used
    only at smoke scale).
    """
    if _use_pallas():
        from repro.kernels import gmm as _k

        return _k.gmm(x, w, group_sizes, tile_t=tile_t,
                      interpret=not jax.default_backend() == "tpu")
    t = x.shape[0]
    e = w.shape[0]
    bounds = jnp.cumsum(group_sizes)
    gid = jnp.sum(jnp.arange(t)[:, None] >= bounds[None, :], axis=-1)
    onehot = jax.nn.one_hot(gid, e, dtype=x.dtype)  # (T, E)
    xe = jnp.einsum("td,te->etd", x, onehot)
    ye = jnp.einsum("etd,edf->etf", xe, w.astype(x.dtype))
    return jnp.einsum("etf,te->tf", ye, onehot)
