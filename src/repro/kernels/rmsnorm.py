"""Fused RMSNorm Pallas TPU kernel.

Tiles rows into VMEM blocks of (block_rows, d); each program computes the
mean-square and scales in one pass (one HBM read, one HBM write — the fusion
the paper's Ascend kernel provides).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Deployment envelope for the VMEM budget check (tools/analyze kernel-shapes):
# widest config-zoo d_model is 8192 (qwen1.5-110b).
VMEM_BOUNDS = {"d": 8192}


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-5,
            block_rows: int = 128, interpret: bool = False) -> jnp.ndarray:
    """x: (rows, d), w: (d,).  d should be a multiple of 128 on real TPU."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows //= 2
    block_rows = max(block_rows, 1)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, w)
