"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernel tests assert against, and the CPU
execution path used when the TPU backend is absent (this container).  They are
written for clarity, not speed.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm over the last dim.  x: (..., d), w: (d,).

    f32 is used ONLY for the variance reduction; the scale is applied in the
    storage dtype so no (B,S,d)-sized f32 buffer is materialized (the fused
    TPU kernel does the same in VMEM — §Perf log, qwen1.5-110b)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = (jax_rsqrt(var + eps)).astype(x.dtype)
    return x * inv * w.astype(x.dtype)


def jax_rsqrt(x):
    return 1.0 / jnp.sqrt(x)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    """Fused SwiGLU activation: silu(gate) * up."""
    g32 = gate.astype(jnp.float32)
    return (g32 * (1.0 / (1.0 + jnp.exp(-g32))) * up.astype(jnp.float32)).astype(
        gate.dtype
    )


def rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate-half RoPE application.

    x:   (..., d)  with the first/second half-split convention (llama).
    cos: (..., d//2) broadcastable against x's leading dims.
    sin: (..., d//2)
    """
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos.astype(jnp.float32)
    s = sin.astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    """Naive GQA attention oracle.

    q: (B, Sq, H, d);  k, v: (B, Sk, KV, d) with H % KV == 0.
    ``window`` > 0 masks keys older than ``window`` positions (sliding window).
    Assumes q positions are the LAST Sq positions of the Sk context
    (Sq == Sk for self-attention; Sq == 1 for decode).
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    group = h // kv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, sq, kv, group, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) * scale
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def gmm(
    x: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray
) -> jnp.ndarray:
    """Grouped matmul oracle (MoE expert FFN building block).

    x: (T, d) rows sorted by group;  w: (E, d, f);  group_sizes: (E,) int32,
    sum(group_sizes) == T.  Row t is multiplied by w[g(t)].
    """
    t = x.shape[0]
    e = w.shape[0]
    # group id per row from cumulative sizes
    bounds = jnp.cumsum(group_sizes)
    row = jnp.arange(t)
    gid = jnp.sum(row[:, None] >= bounds[None, :], axis=-1)  # (T,)
    wg = w[gid]  # (T, d, f) — oracle only; the kernel never materializes this
    return jnp.einsum(
        "td,tdf->tf", x.astype(jnp.float32), wg.astype(jnp.float32)
    ).astype(x.dtype)
