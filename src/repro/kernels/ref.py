"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernel tests assert against, and the CPU
execution path used when the TPU backend is absent (this container).  They are
written for clarity, not speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm over the last dim.  x: (..., d), w: (d,).

    f32 is used ONLY for the variance reduction; the scale is applied in the
    storage dtype so no (B,S,d)-sized f32 buffer is materialized (the fused
    TPU kernel does the same in VMEM — §Perf log, qwen1.5-110b)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = (jax_rsqrt(var + eps)).astype(x.dtype)
    return x * inv * w.astype(x.dtype)


def jax_rsqrt(x):
    return 1.0 / jnp.sqrt(x)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    """Fused SwiGLU activation: silu(gate) * up."""
    g32 = gate.astype(jnp.float32)
    return (g32 * (1.0 / (1.0 + jnp.exp(-g32))) * up.astype(jnp.float32)).astype(
        gate.dtype
    )


def rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate-half RoPE application.

    x:   (..., d)  with the first/second half-split convention (llama).
    cos: (..., d//2) broadcastable against x's leading dims.
    sin: (..., d//2)
    """
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos.astype(jnp.float32)
    s = sin.astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    """Naive GQA attention oracle.

    q: (B, Sq, H, d);  k, v: (B, Sk, KV, d) with H % KV == 0.
    ``window`` > 0 masks keys older than ``window`` positions (sliding window).
    Assumes q positions are the LAST Sq positions of the Sk context
    (Sq == Sk for self-attention; Sq == 1 for decode).
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    group = h // kv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, sq, kv, group, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) * scale
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def paged_decode_attention(
    q: jnp.ndarray,
    k_new: jnp.ndarray,
    v_new: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    tables: jnp.ndarray,
    pos: jnp.ndarray,
    *,
    block_size: int,
    window: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    """One-token attention straight off the paged KV pool (chunked, exact).

    q:             (S, 1, H, d)  per-slot decode queries (one layer)
    k_new / v_new: (S, KV, d)    the in-flight token's KV (not yet in the pool)
    pool_k/pool_v: (R, KV, d)    one layer's row pool (serve/paged_cache.py)
    tables:        (S, MB) int32 block table; pos: (S,) int32 cached rows.

    Chunked two-pass softmax, NOT online: scores are computed block-by-block
    (a ``fori_loop`` whose trip count is the number of LIVE blocks, so work
    scales with cached tokens, not pool capacity), the softmax runs once over
    the assembled (S, KV, G, MB*bs) score tensor, and the value contraction
    accumulates one row per step in logical order.  Every float op then has
    the same shape and reduction order as ``ops.decode_attention`` over the
    dense-gathered view, which keeps this path BITWISE equal to the dense
    oracle — the serving engine's bit-compatibility contract with the
    synchronized ``RolloutEngine`` rides on it (tested).  An online-softmax
    single-pass (the Pallas kernel's form) would round the rescales
    differently and break greedy ``gen_logp`` equality.

    Rows at logical position > pos (and outside ``window``, when > 0) are
    masked to -1e30, exactly like ``_decode_pos_valid``; the in-flight token
    occupies logical position ``pos`` itself, substituted into its block so
    the score/value ops see what the dense path sees after cache insertion.
    """
    s_, _, h, d = q.shape
    kv = pool_k.shape[1]
    g = h // kv
    mb = tables.shape[1]
    cap = mb * block_size
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    qg = (q.reshape(s_, kv, g, d) * scale).astype(pool_k.dtype)
    ar = jnp.arange(cap)
    valid = ar[None, :] <= pos[:, None]
    if window > 0:
        valid &= ar[None, :] > pos[:, None] - window
    boff = jnp.arange(block_size)
    nb_live = jnp.max(pos) // block_size + 1    # blocks covering rows 0..pos

    def score_block(bi, sc):
        tcol = jax.lax.dynamic_index_in_dim(tables, bi, 1, keepdims=False)
        rows = tcol[:, None] * block_size + boff[None, :]       # (S, bs)
        kblk = pool_k[rows]                                     # (S,bs,KV,d)
        is_new = (bi * block_size + boff)[None, :] == pos[:, None]
        kblk = jnp.where(is_new[..., None, None], k_new[:, None], kblk)
        sblk = jnp.einsum("bkgd,bskd->bkgs", qg, kblk,
                          preferred_element_type=jnp.float32)
        return jax.lax.dynamic_update_slice_in_dim(sc, sblk, bi * block_size,
                                                   axis=3)

    sc = jax.lax.fori_loop(0, nb_live, score_block,
                           jnp.full((s_, kv, g, cap), -1e30, jnp.float32))
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    pc = p.astype(pool_v.dtype)

    def value_row(j, acc):
        tcol = jax.lax.dynamic_index_in_dim(tables, j // block_size, 1,
                                            keepdims=False)
        vrow = pool_v[tcol * block_size + j % block_size]       # (S, KV, d)
        vrow = jnp.where((pos == j)[:, None, None], v_new, vrow)
        pj = jax.lax.dynamic_slice_in_dim(pc, j, 1, axis=3)[..., 0]
        return acc + jnp.einsum("bkg,bkd->bkgd", pj, vrow,
                                preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, jnp.max(pos) + 1, value_row,
                            jnp.zeros((s_, kv, g, d), jnp.float32))
    return acc.reshape(s_, 1, h, d).astype(q.dtype)


def gmm(
    x: jnp.ndarray, w: jnp.ndarray, group_sizes: jnp.ndarray
) -> jnp.ndarray:
    """Grouped matmul oracle (MoE expert FFN building block).

    x: (T, d) rows sorted by group;  w: (E, d, f);  group_sizes: (E,) int32,
    sum(group_sizes) == T.  Row t is multiplied by w[g(t)].
    """
    t = x.shape[0]
    # group id per row from cumulative sizes
    bounds = jnp.cumsum(group_sizes)
    row = jnp.arange(t)
    gid = jnp.sum(row[:, None] >= bounds[None, :], axis=-1)  # (T,)
    wg = w[gid]  # (T, d, f) — oracle only; the kernel never materializes this
    return jnp.einsum(
        "td,tdf->tf", x.astype(jnp.float32), wg.astype(jnp.float32)
    ).astype(x.dtype)
