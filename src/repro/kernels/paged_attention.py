"""Paged decode-attention Pallas TPU kernel (flash-decoding over block tables).

The serving hot loop decodes one token per slot per step against KV that
lives in the paged pool (serve/paged_cache.py).  Before this kernel, the
engine materialized a dense ``(layers, slots, max_blocks*block_size, kv, hd)``
copy of the pool every step (``gather_kv``) and ran dense attention on it —
decode cost scaled with pool *capacity*, not live tokens.  Here attention
reads the block table directly:

  grid = (layer, slot, kv_block)

The block table and per-slot positions ride in as SCALAR-PREFETCH operands
(the same trick as ``gather_pool_pallas``): the pool BlockSpec's index map
looks up ``tbl[slot, block]`` so each program DMAs exactly the pool block its
table entry names.  The innermost grid dimension walks a slot's blocks
sequentially; VMEM scratch carries the flash-decoding online-softmax partials
``(acc, m, l)`` across blocks, initialized at block 0 and finalized at the
last block, where the in-flight token's (k, v) — not yet scattered into the
pool — is folded in as the final softmax element before normalization.

Masking: rows at logical position ``>= pos[slot]`` (null-block rows,
beyond-length rows, idle slots) are masked to -1e30 so they contribute
nothing; blocks that start at or beyond ``pos`` skip their update entirely
via ``pl.when`` (their table entries all name the null block, so the dead
DMAs at least all hit one hot block).  A fully-masked first block can leak
``exp(0)`` garbage into the partials while ``m == -1e30``; the next real
(or final-token) rescale multiplies it by ``exp(-1e30 - m_new) == 0``, so
the result is still exact — the standard flash-decoding identity.

Numerics: online softmax is mathematically identical to dense softmax but
not bitwise (rescaling rounds differently); the engine's bit-compatibility
oracle is the jnp reference (kernels/ref.py), which is two-pass and bitwise
equal to the dense-gather path.  Greedy decode is identical across all
three (tested in tests/test_paged_attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Deployment envelope for the VMEM budget check (tools/analyze kernel-shapes):
# up to 64 query heads over 8 KV heads of head_dim 128, pool blocks of at
# most 64 rows.  Worst case well under 1 MiB/program.
VMEM_BOUNDS = {"h": 64, "hd": 128, "kv": 8, "block_size": 64}


def _paged_decode_kernel(tbl_ref, pos_ref, q_ref, kn_ref, vn_ref, kb_ref,
                         vb_ref, o_ref, acc_ref, m_ref, l_ref, *,
                         block_size: int, nb: int, kv: int, g: int, hd: int,
                         window: int, scale: float):
    i = pl.program_id(1)      # slot
    j = pl.program_id(2)      # kv block (innermost: sequential per slot)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    p = pos_ref[i]
    start = j * block_size

    @pl.when(start < p)       # block holds at least one cached row (< pos)
    def _block():
        q = q_ref[0, 0].reshape(kv, g, hd).astype(jnp.float32) * scale
        kblk = kb_ref[0, 0].reshape(block_size, kv, hd).astype(jnp.float32)
        vblk = vb_ref[0, 0].reshape(block_size, kv, hd).astype(jnp.float32)
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, (block_size, 1),
                                                0)[:, 0]
        s = jnp.einsum("kgd,skd->kgs", q, kblk,
                       preferred_element_type=jnp.float32)
        valid = kpos < p
        if window > 0:
            valid &= kpos > p - window
        s = jnp.where(valid[None, None, :], s, -1e30)
        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + jnp.sum(pexp, axis=-1)
        acc_ref[...] = acc_prev * corr[..., None] + jnp.einsum(
            "kgs,skd->kgd", pexp, vblk, preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)     # fold the in-flight token, then normalize
    def _final():
        q = q_ref[0, 0].reshape(kv, g, hd).astype(jnp.float32) * scale
        kn = kn_ref[0, 0].reshape(kv, hd).astype(jnp.float32)
        vn = vn_ref[0, 0].reshape(kv, hd).astype(jnp.float32)
        s1 = jnp.einsum("kgd,kd->kg", q, kn,
                        preferred_element_type=jnp.float32)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s1)
        corr = jnp.exp(m_prev - m_new)
        p1 = jnp.exp(s1 - m_new)
        l = l_ref[...] * corr + p1
        acc = acc_ref[...] * corr[..., None] + p1[..., None] * vn[:, None]
        o_ref[0, 0] = (acc / l[..., None]).reshape(kv * g * hd).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_size", "window", "scale",
                                             "interpret"))
def paged_decode_attention(q, k_new, v_new, pool_k, pool_v, tables, pos, *,
                           block_size: int, window: int = 0,
                           scale: float | None = None,
                           interpret: bool = False) -> jnp.ndarray:
    """One-token attention straight off the paged pool.

    q:             (n, S, H, hd)   per-slot decode queries
    k_new / v_new: (n, S, KV, hd)  the in-flight token's KV (not in the pool)
    pool_k/pool_v: (n, R, KV, hd)  row pools, R = (num_blocks + 1) * block_size
    tables:        (S, MB) int32   block table (scalar prefetch)
    pos:           (S,) int32      cached rows per slot (write position)

    Returns (n, S, H, hd).  The model's layer scan calls this with n == 1;
    the kernel is written for the general (layer, slot, kv_block) grid.

    Pool rows R must be a multiple of block_size and H a multiple of KV.
    """
    from jax.experimental.pallas import tpu as pltpu

    n, s, h, hd = q.shape
    kv = pool_k.shape[2]
    assert pool_k.shape[1] % block_size == 0, \
        f"pool rows {pool_k.shape[1]} must be a multiple of {block_size}"
    assert h % kv == 0, f"query heads {h} must group evenly over {kv} KV heads"
    g = h // kv
    _, mb = tables.shape
    scale = scale if scale is not None else 1.0 / float(np.sqrt(hd))
    poolk4 = pool_k.reshape(n, -1, block_size, kv * hd)
    poolv4 = pool_v.reshape(n, -1, block_size, kv * hd)
    q3 = q.reshape(n, s, h * hd)
    kn3 = k_new.reshape(n, s, kv * hd)
    vn3 = v_new.reshape(n, s, kv * hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n, s, mb),
        in_specs=[
            pl.BlockSpec((1, 1, h * hd), lambda l, i, j, tbl, ps: (l, i, 0)),
            pl.BlockSpec((1, 1, kv * hd), lambda l, i, j, tbl, ps: (l, i, 0)),
            pl.BlockSpec((1, 1, kv * hd), lambda l, i, j, tbl, ps: (l, i, 0)),
            pl.BlockSpec((1, 1, block_size, kv * hd),
                         lambda l, i, j, tbl, ps: (l, tbl[i, j], 0, 0)),
            pl.BlockSpec((1, 1, block_size, kv * hd),
                         lambda l, i, j, tbl, ps: (l, tbl[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, h * hd),
                               lambda l, i, j, tbl, ps: (l, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g, hd), jnp.float32),   # acc
            pltpu.VMEM((kv, g), jnp.float32),       # m (running max)
            pltpu.VMEM((kv, g), jnp.float32),       # l (running denom)
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, block_size=block_size, nb=mb,
                          kv=kv, g=g, hd=hd, window=window, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, s, h * hd), q.dtype),
        interpret=interpret,
    )(tables, pos, q3, kn3, vn3, poolk4, poolv4)
    return out.reshape(n, s, h, hd)
