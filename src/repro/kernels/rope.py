"""Fused rotate-half RoPE application Pallas TPU kernel.

x: (B, S, H, d) with cos/sin (B, S, d//2); the rotation is applied in one
VMEM pass per (batch, seq-block) tile across all heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Deployment envelope for the VMEM budget check (tools/analyze kernel-shapes):
# up to 64 heads of head_dim 128 in the config zoo.
VMEM_BOUNDS = {"h": 64, "d": 128}


def _rope_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (1, bs, H, d)
    c = cos_ref[...].astype(jnp.float32)        # (1, bs, d//2)
    s = sin_ref[...].astype(jnp.float32)
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = c[:, :, None, :]                        # broadcast over heads
    s = s[:, :, None, :]
    o = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray, *,
               block_s: int = 128, interpret: bool = False) -> jnp.ndarray:
    """x: (B, S, H, d); cos/sin: (B, S, d//2) (or broadcastable (1, S, d//2)).

    d must be even (rotate-half splits the feature dim in two)."""
    b, s, h, d = x.shape
    assert d % 2 == 0, f"rotate-half RoPE needs an even head dim, got {d}"
    cos = jnp.broadcast_to(cos, (b, s, d // 2))
    sin = jnp.broadcast_to(sin, (b, s, d // 2))
    block_s = min(block_s, s)
    while s % block_s:
        block_s //= 2
    block_s = max(block_s, 1)
    grid = (b, s // block_s)
    return pl.pallas_call(
        _rope_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, h, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, d // 2), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_s, d // 2), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, h, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), x.dtype),
        interpret=interpret,
    )(x, cos, sin)
