"""FlashAttention Pallas TPU kernel (causal + sliding-window, GQA).

Grid: (batch, kv_head, q_block).  Each program holds one q tile
(block_q, group*d) in VMEM and streams k/v blocks with an online-softmax
accumulator.  Tile sizes are MXU-aligned (multiples of 128 at full scale).

The q/k block loop bound is static; causal and sliding-window masking skip
out-of-range blocks by zero-masking (interpret-mode friendly; on real TPU the
``when`` predication prunes them).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Deployment envelope for the VMEM budget check (tools/analyze kernel-shapes):
# largest config-zoo model has head_dim 128, 8 KV heads under 64 query heads
# (group 8), and serve contexts up to 4k.  Worst case ~5 MiB/program.
VMEM_BOUNDS = {"g": 8, "d": 128, "sk": 4096}


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
                  window: int, scale: float, sq: int, sk: int):
    # q_ref: (1, 1, block_q, g, d); k_ref/v_ref: (1, 1, sk, d)
    q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, g, d)
    bq, g, d = q.shape
    qi = pl.program_id(2)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq) + (sk - sq)
    nb = sk // block_k

    def body(i, carry):
        acc, m, l = carry
        kblk = k_ref[0, 0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        vblk = v_ref[0, 0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        k_pos = i * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jax.lax.dot_general(
            q.reshape(bq * g, d), kblk,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(bq, g, block_k)
        mask = jnp.ones((bq, block_k), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[:, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.reshape(bq * g, block_k), vblk,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(bq, g, d)
        acc_new = acc * corr[..., None] + pv
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, g, d), jnp.float32)
    m0 = jnp.full((bq, g), -1e30, jnp.float32)
    l0 = jnp.zeros((bq, g), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nb, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, H, d); k, v: (B, Sk, KV, d).  Returns (B, Sq, H, d)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0, f"query heads {h} must group evenly over {kv} KV heads"
    g = h // kv
    scale = scale if scale is not None else 1.0 / float(np.sqrt(d))
    block_q = min(block_q, sq)
    while sq % block_q:
        block_q //= 2
    block_q = max(block_q, 1)
    block_k = min(block_k, sk)
    while sk % block_k:
        block_k //= 2
    block_k = max(block_k, 1)

    qg = q.reshape(b, sq, kv, g, d).transpose(0, 2, 1, 3, 4)  # (B,KV,Sq,g,d)
    kt = k.transpose(0, 2, 1, 3)                              # (B,KV,Sk,d)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, kv, sq // block_q)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, block_k=block_k, causal=causal, window=window,
            scale=scale, sq=sq, sk=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, g, d), lambda i, j, n: (i, j, n, 0, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda i, j, n: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda i, j, n: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, g, d), lambda i, j, n: (i, j, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, sq // block_q * block_q, g, d),
                                       q.dtype),
        interpret=interpret,
    )(qg, kt, vt)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, sq, h, d)
