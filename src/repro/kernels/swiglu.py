"""Fused SwiGLU Pallas TPU kernel: out = silu(gate) * up.

Avoids materializing silu(gate) in HBM (the fusion the paper integrates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.nn.sigmoid(g) * u).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_f", "interpret"))
def swiglu(gate: jnp.ndarray, up: jnp.ndarray, *, block_rows: int = 256,
           block_f: int = 512, interpret: bool = False) -> jnp.ndarray:
    """gate, up: (rows, f)."""
    rows, f = gate.shape
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows //= 2
    block_rows = max(block_rows, 1)
    block_f = min(block_f, f)
    while f % block_f:
        block_f //= 2
    block_f = max(block_f, 1)
    grid = (rows // block_rows, f // block_f)
    spec = pl.BlockSpec((block_rows, block_f), lambda i, j: (i, j))
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, f), gate.dtype),
        interpret=interpret,
    )(gate, up)
