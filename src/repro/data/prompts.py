"""Prompt datasets for RL training.

The paper uses DeepScaleR (math prompts) with a rule reward.  Offline, we
ship two synthetic rule-reward tasks of the same *shape* (prompt in, response
scored by a deterministic rule):

  * ``pattern_task``    — prompt names a target byte; reward = fraction of
    response tokens equal to it.  Learnable by a tiny model in ~100 steps.
  * ``arithmetic_task`` — prompt is "a+b="; reward 1 if the decoded response
    starts with the correct sum (sparse; harder).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.tokenizer import ByteTokenizer


@dataclasses.dataclass
class RuleTask:
    name: str
    make_prompt: callable          # rng -> (text, meta)
    reward_fn: callable            # (meta, response_text, response_ids) -> float


def pattern_task() -> RuleTask:
    letters = "abcdefgh"

    def make_prompt(rng: np.random.Generator):
        c = letters[rng.integers(len(letters))]
        return f"repeat {c}:", {"target": ord(c)}

    def reward(meta, text, ids):
        ids = [i for i in ids if i < 256]
        if not ids:
            return 0.0
        return float(np.mean([i == meta["target"] for i in ids]))

    return RuleTask("pattern", make_prompt, reward)


def arithmetic_task() -> RuleTask:
    def make_prompt(rng: np.random.Generator):
        a, b = int(rng.integers(0, 50)), int(rng.integers(0, 50))
        return f"{a}+{b}=", {"sum": a + b}

    def reward(meta, text, ids):
        return float(text.strip().startswith(str(meta["sum"])))

    return RuleTask("arithmetic", make_prompt, reward)


class PromptDataset:
    """Infinite sampler of (padded prompt ids, lengths, metas)."""

    def __init__(self, task: RuleTask, tokenizer: ByteTokenizer | None = None,
                 max_prompt_len: int = 64, seed: int = 0):
        self.task = task
        self.tok = tokenizer or ByteTokenizer()
        self.max_prompt_len = max_prompt_len
        self.rng = np.random.default_rng(seed)

    def sample(self, n: int):
        texts, metas, idlists = [], [], []
        for _ in range(n):
            text, meta = self.task.make_prompt(self.rng)
            ids = self.tok.encode(text)
            texts.append(text)
            metas.append(meta)
            idlists.append(ids)
        lengths = np.array([min(len(i), self.max_prompt_len) for i in idlists],
                           np.int32)
        batch = self.tok.pad_batch(idlists, self.max_prompt_len)
        return batch, lengths, metas

    def score(self, metas, response_ids: np.ndarray) -> np.ndarray:
        """response_ids: (n, T) int32 (may contain pad/eos)."""
        out = np.zeros(len(metas), np.float32)
        for i, meta in enumerate(metas):
            ids = list(response_ids[i])
            if ByteTokenizer.eos_id in ids:
                ids = ids[: ids.index(ByteTokenizer.eos_id)]
            text = self.tok.decode(ids)
            out[i] = self.task.reward_fn(meta, text, ids)
        return out
