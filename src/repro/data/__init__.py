from repro.data.tokenizer import ByteTokenizer  # noqa: F401
from repro.data.prompts import PromptDataset, arithmetic_task, pattern_task  # noqa: F401
