"""Byte-level tokenizer (offline stand-in for the DeepScaleR prompt set's
tokenizer).  Vocab: 256 bytes + specials."""
from __future__ import annotations

import numpy as np

PAD, BOS, EOS = 256, 257, 258
VOCAB = 259


class ByteTokenizer:
    pad_id, bos_id, eos_id = PAD, BOS, EOS
    vocab_size = VOCAB

    def encode(self, text: str, add_bos: bool = True) -> list[int]:
        ids = list(text.encode("utf-8"))
        return ([BOS] if add_bos else []) + ids

    def decode(self, ids) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")

    def pad_batch(self, seqs, length: int) -> np.ndarray:
        out = np.full((len(seqs), length), PAD, np.int32)
        for i, s in enumerate(seqs):
            s = s[:length]
            out[i, :len(s)] = s
        return out
