"""AdamW in pure JAX (no optax).

Moments are fp32 regardless of param dtype (bf16 params + fp32 moments is the
memory layout the resharding flow ledgers assume).  State is a pytree shaped
like params, so every sharding rule for params applies verbatim to state —
plus an optional ZeRO transform applied at the sharding layer.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros32, params),
        nu=jax.tree.map(zeros32, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, state: AdamWState, params, *, lr, betas=(0.9, 0.95),
                 eps: float = 1e-8, weight_decay: float = 0.0,
                 grad_clip: float = 0.0):
    """Returns (new_params, new_state).  ``lr`` may be a scalar or a
    schedule value already evaluated at ``state.step``."""
    b1, b2 = betas
    step = state.step + 1
    if grad_clip > 0:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
