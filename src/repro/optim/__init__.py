from repro.optim.adamw import (  # noqa: F401
    adamw_init,
    adamw_update,
    global_norm,
)
from repro.optim.schedule import constant_schedule, cosine_schedule, wsd_schedule  # noqa: F401
