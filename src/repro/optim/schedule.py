"""Learning-rate schedules (pure functions of the int step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_schedule(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(lr) * jnp.where(s < warmup, warm, cos)
    return f


def wsd_schedule(lr: float, warmup: int, stable: int, decay: int,
                 min_ratio: float = 0.05):
    """Warmup–stable–decay."""
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        dec = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        val = jnp.where(s < warmup, warm,
                        jnp.where(s < warmup + stable, 1.0,
                                  1.0 - (1 - min_ratio) * dec))
        return jnp.float32(lr) * val
    return f
