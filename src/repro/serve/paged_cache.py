"""Block-table paged KV cache (the serving-side memory manager).

The synchronized ``RolloutEngine`` allocates a dense ``(B, capacity)`` cache:
every sequence owns ``capacity`` slots for its whole life, which is exactly
the KV memory waste the paper's allgather-swap work fights on the weight
side.  Here KV lives in fixed-size BLOCKS:

  pool_k / pool_v : (num_layers, (num_blocks + 1) * block_size, kv, hd)

i.e. a flat row pool; block ``i`` owns rows ``[i*bs, (i+1)*bs)``.  The LAST
block is the **null block**: unassigned block-table entries point there, so
KV writes from idle serving slots land in it and reads of it are masked by
the attention validity mask — no per-slot branching inside the jitted step.

A slot's logical cache is described by one row of a block table
``(max_slots, max_blocks_per_seq) int32``; logical position ``j`` lives at
flat row ``table[j // bs] * bs + j % bs``.

The serving DECODE path never materializes a dense per-slot view: attention
reads the block tables directly (kernels/paged_attention.py — flash-decoding
Pallas kernel on TPU, chunked bitwise-exact jnp reference elsewhere), so the
paged cache is a speed win as well as a memory win — decode-step cost scales
with live tokens, not ``max_blocks_per_seq``.  ``gather_kv`` (Pallas
block-read kernel + advanced-index reference) survives only behind
``dense_view()`` as a debugging aid and the bit-compatibility oracle the
paged kernels are tested against.

Blocks are REF-COUNTED and PREFIX-INDEXED (vLLM's prefix caching, on the
paper's observation that GRPO's sample flow is maximally redundant at
admission — every group of N rollouts re-prefills the same prompt, and every
partial-rollout resume re-prefills a prefix that did not change):

  * ``alloc()`` hands out a block with refcount 1; ``share()`` takes an extra
    reference on a resident block (a prefix-cache hit); ``free()`` only
    DECREMENTS — a block returns to the free structure when its refcount
    hits zero, so N requests can read one prompt-head block concurrently.
  * ``register(key, block)`` indexes a FULL block under a chained hash of
    the entire token prefix it caches (``prefix_key``: H(parent_key ||
    block tokens), O(block) per extension); ``lookup(key)`` is how the
    scheduler matches a new request's block-aligned prompt head against
    resident blocks at admission.
  * A freed block KEEPS its content and index entry (it may be revived by a
    later ``share()``); the entry is dropped only when ``alloc()`` actually
    reclaims the block.  Eviction order is least-recently-freed first: the
    free structure is a ``deque`` (append on free, pop-left on reclaim)
    mirrored by a set — revival just removes the set entry and ``alloc()``
    skips the stale deque entry lazily, keeping every operation O(1).
  * ``flush_index()`` drops ALL index entries (allocations untouched) — the
    engine calls it when the policy weights change, because cached KV from
    the previous weights must never satisfy a prefix match under the new
    ones (partial rollout accepts a mildly off-policy RESUME, not silently
    stale KV).

With a HOST TIER attached (serve/host_tier.py), reclaiming an indexed
block SPILLS it instead of dropping it: ``alloc()`` moves the content and
index entry down to host RAM (async ``device_get``), ``lookup_host()``
matches it there, and ``swap_in()`` streams it back into a device block
(async ``device_put``).  A prefix key lives in exactly ONE tier at a time.
Only PREFILL-provenance blocks spill: a block some decode step wrote into
(``mark_decode_write``) is dropped on reclaim exactly as without the tier,
because decode-written KV bytes are not bit-reproducible by re-prefill
(backend matmul tiling differs by batch shape) and swapping them in would
break the greedy tier-on/off bit-identity contract.
The pools are exposed as properties whose getter applies any completed
swap-ins first (``_apply_swap_ins`` — the drain point), so every compute
and every spill reads fully-arrived rows and step order stays
deterministic no matter how the async engine is scheduled.
"""
from __future__ import annotations

import functools
import hashlib
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.serve.host_tier import SwapWorkerError


def blocks_for(ntokens: int, block_size: int) -> int:
    return -(-ntokens // block_size)


def prefix_key(parent: bytes, block_tokens) -> bytes:
    """Chained per-block index key: H(parent_key || this block's token
    bytes) — vLLM's prefix-hash design.  The chain makes each key O(block)
    to extend (walking a stream's blocks is O(stream) total, and memoizable
    per request) while still identifying the ENTIRE prefix; 16-byte blake2b
    digests make collisions a non-concern next to f32 rollout numerics.
    The root block's parent is ``b""``."""
    h = hashlib.blake2b(parent, digest_size=16)
    h.update(np.ascontiguousarray(block_tokens, dtype=np.int32).tobytes())
    return h.digest()


# ---------------------------------------------------------------------------
# gather: pool rows -> dense per-slot view
# ---------------------------------------------------------------------------

def flat_indices(tables: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """tables: (S, MB) int32 -> flat pool row per (slot, logical pos):
    (S, MB * block_size) int32."""
    cap = tables.shape[1] * block_size
    j = jnp.arange(cap, dtype=jnp.int32)
    return tables[:, j // block_size] * block_size + j % block_size


def gather_pool_ref(pool: jnp.ndarray, tables: jnp.ndarray,
                    block_size: int) -> jnp.ndarray:
    """pool: (n, R, kv, hd); tables: (S, MB) -> (n, S, MB*bs, kv, hd)."""
    return pool[:, flat_indices(tables, block_size)]


# Deployment envelope for the VMEM budget check (tools/analyze kernel-shapes):
# pool blocks of at most 64 rows, 8 KV heads of head_dim 128 — one block of
# each of the in/out specs is 256 KiB.
VMEM_BOUNDS = {"block_size": 64, "kv": 8, "hd": 128}


def _gather_block_kernel(tbl_ref, pool_ref, o_ref):
    del tbl_ref  # consumed by the index maps (scalar prefetch)
    o_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def gather_pool_pallas(pool: jnp.ndarray, tables: jnp.ndarray,
                       block_size: int, interpret: bool = False) -> jnp.ndarray:
    """Pallas block-read kernel: grid (layer, slot, block); the block table is
    a scalar-prefetch operand so each program DMAs exactly the pool block its
    table entry names (vLLM's paged attention gather, at the memory level)."""
    from jax.experimental.pallas import tpu as pltpu

    n, rows, kv, hd = pool.shape
    s, mb = tables.shape
    k = kv * hd
    pool4 = pool.reshape(n, rows // block_size, block_size, k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, s, mb),
        in_specs=[pl.BlockSpec((1, 1, block_size, k),
                               lambda l, i, j, tbl: (l, tbl[i, j], 0, 0))],
        out_specs=pl.BlockSpec((1, 1, block_size, k),
                               lambda l, i, j, tbl: (l, i, j, 0)),
    )
    out = pl.pallas_call(
        _gather_block_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, s, mb * block_size, k), pool.dtype),
        interpret=interpret,
    )(tables, pool4)
    return out.reshape(n, s, mb * block_size, kv, hd)


def gather_kv(pool_k: jnp.ndarray, pool_v: jnp.ndarray, tables: jnp.ndarray,
              block_size: int) -> dict:
    """Dense {"k", "v"} view of the paged pools — the cache pytree the
    model-zoo ``decode`` consumes.  Dispatches like kernels/ops.py: Pallas on
    TPU (or REPRO_PALLAS=interpret), jnp reference elsewhere."""
    if ops._use_pallas():
        interp = not jax.default_backend() == "tpu"
        return {"k": gather_pool_pallas(pool_k, tables, block_size, interp),
                "v": gather_pool_pallas(pool_v, tables, block_size, interp)}
    return {"k": gather_pool_ref(pool_k, tables, block_size),
            "v": gather_pool_ref(pool_v, tables, block_size)}


# ---------------------------------------------------------------------------
# scatter: step / prefill writes into the pool
# ---------------------------------------------------------------------------

def scatter_token(pool: jnp.ndarray, rows: jnp.ndarray,
                  flat_pos: jnp.ndarray) -> jnp.ndarray:
    """Write one decode step's KV.  rows: (n, S, kv, hd); flat_pos: (S,) —
    idle slots' tables route their write to the null block."""
    return pool.at[:, flat_pos].set(rows)


def scatter_prefill(pool: jnp.ndarray, rows: jnp.ndarray,
                    flat_rows: jnp.ndarray) -> jnp.ndarray:
    """Write one sequence's prefill KV.  rows: (n, P, kv, hd); flat_rows: (P,)."""
    return pool.at[:, flat_rows].set(rows)


# swap-in landing write (one block of rows); donation keeps the drain point
# allocation-free just like the engine's prefill writes
_swap_write = jax.jit(scatter_prefill, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# the cache object (pool arrays + block allocator)
# ---------------------------------------------------------------------------

class PagedKVCache:
    """Owns the block pools, the ref-counted free structure and the prefix
    index.  Layout-compatible with the transformer-family dense cache:
    gathering a slot's blocks reproduces the ``init_cache``/``prefill`` row
    content bit-for-bit, which is what makes ``ServingEngine.generate``
    bit-compatible with ``RolloutEngine``."""

    def __init__(self, cfg: ModelConfig, *, num_blocks: int, block_size: int,
                 max_blocks_per_seq: int, host=None):
        if cfg.num_kv_heads <= 0:
            raise ValueError(
                f"paged KV cache needs an attention cache; arch "
                f"{cfg.name!r} ({cfg.arch_type}) has no KV heads")
        if host is not None and host.block_size != block_size:
            raise ValueError(
                f"host tier block_size {host.block_size} != device "
                f"block_size {block_size}")
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.null_block = num_blocks          # last block = write sink
        self.host = host                      # HostKVTier | None
        self._pending_in = 0                  # swap-ins scheduled, unscattered
        # swap-failure degradation state (docs/resilience.md): a worker
        # failure anywhere funnels through _apply_swap_ins at the next pool
        # read — the read barrier every compute passes — which detaches the
        # tier (host -> None), drops garbage-row index entries and records
        # the garbage blocks for the engine to preempt
        self._host_error = None               # pending failure -> degrade at
        #                                       the next pool-read barrier
        self.degraded = False                 # tier was dropped this session
        self._degraded_blocks: set[int] = set()  # swap-in targets whose
        #                                       upload failed (garbage rows)
        n, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        rows = (num_blocks + 1) * block_size
        dt = L.cdtype(cfg)
        self._pool_k = jnp.zeros((n, rows, kv, hd), dt)
        self._pool_v = jnp.zeros((n, rows, kv, hd), dt)
        self._ref = [0] * num_blocks          # per-block reference counts
        # ref-0 blocks in eviction order (least-recently freed first).  The
        # deque holds (block, epoch) entries and may hold STALE ones for
        # blocks share() revived — each free() bumps the block's epoch, so
        # alloc() recognizes an entry as live only if it is the block's
        # NEWEST free (epoch match) and the block is still in the mirror
        # set.  That keeps eviction order exact under free/revive/free
        # churn while every operation stays O(1).
        self._free_epoch = [0] * num_blocks
        self._free = deque((b, 0) for b in range(num_blocks))
        self._free_set = set(range(num_blocks))
        self._index: dict[bytes, int] = {}    # prefix_key -> block
        self._block_key: dict[int, bytes] = {}  # block -> its index key
        # blocks whose CURRENT content includes decode-written rows.  Spill
        # is restricted to prefill-provenance blocks: prefill rows recompute
        # bit-identically (the chunk-invariance contract), but a decode-
        # written row does NOT — backends tile a [S,1,d] decode projection
        # differently from a [1,T,d] prefill, so the same token's KV row
        # differs in low bits by code path.  Swapping decode-era bytes back
        # in would therefore break the greedy tier-on/off bit-identity
        # contract (recompute produces prefill bits).  Decode-tainted
        # blocks still revive from the DEVICE index like always; once
        # reclaimed they are dropped and recomputed, tier or no tier.
        self._decode_written: set[int] = set()

    # -- pools (every read is a swap-in drain point) ------------------------
    # The pools are PROPERTIES so no caller — engine compute, spill slicing,
    # dense_view, benchmarks, tests — can ever observe a block whose swap-in
    # is still in flight: the getter applies completed swap-ins first.  The
    # setters just rebind (the engine's donate-and-rebind step pattern).
    @property
    def pool_k(self) -> jnp.ndarray:
        if self._pending_in:
            self._apply_swap_ins()
        return self._pool_k

    @pool_k.setter
    def pool_k(self, value: jnp.ndarray) -> None:
        self._pool_k = value

    @property
    def pool_v(self) -> jnp.ndarray:
        if self._pending_in:
            self._apply_swap_ins()
        return self._pool_v

    @pool_v.setter
    def pool_v(self, value: jnp.ndarray) -> None:
        self._pool_v = value

    def _apply_swap_ins(self) -> None:
        """Drain point: wait for in-flight swap jobs, scatter every arrived
        host block into its device rows.  A scatter may target a block that
        was freed (even re-allocated) after the swap-in was scheduled;
        ordering keeps that safe — the stale write lands HERE, before any
        later owner's prefill/decode write, because those writes also read
        the pool through the draining getter first.

        This barrier is also where swap-WORKER failures resolve: every
        compute read passes through it, so a failure (raised by the drain,
        or recorded earlier by a submit-side catch) always degrades the
        tier BEFORE any garbage swap-in row becomes readable."""
        if self.host is None:                 # degraded under our feet
            self._pending_in = 0
            return
        err = self._host_error
        self._host_error = None
        try:
            self.host.swap.drain()
        except SwapWorkerError as e:
            err = e
        if err is None:
            for flat, dev_k, dev_v in self.host.swap.pop_ready():
                self._pool_k = _swap_write(self._pool_k, dev_k, flat)
                self._pool_v = _swap_write(self._pool_v, dev_v, flat)
            self._pending_in = 0
            return
        self._degrade_host()

    def _degrade_host(self) -> None:
        """Swap-failure degradation: detach the tier and flip to plain
        recompute-preemption mode.  Completed swap-ins still land (their
        bytes are real); FAILED swap-ins' target blocks hold garbage, so
        their index entries are dropped (never matched again) and the
        blocks are recorded for the engine to preempt their owners —
        recompute re-prefills them bit-identically."""
        tier, self.host = self.host, None
        for flat, dev_k, dev_v in tier.swap.pop_ready():
            self._pool_k = _swap_write(self._pool_k, dev_k, flat)
            self._pool_v = _swap_write(self._pool_v, dev_v, flat)
        for flat in tier.swap.pop_failed():
            b = int(flat[0]) // self.block_size
            self._degraded_blocks.add(b)
            key = self._block_key.pop(b, None)
            if key is not None:
                del self._index[key]
        self._pending_in = 0
        self.degraded = True
        tier.disable()
        tier.metrics.inc("serve.swap.degraded")

    def _host_failure(self, err: SwapWorkerError) -> None:
        """A submit-side call caught a worker failure: remember it and
        force the next pool read through the barrier, which degrades."""
        self._host_error = err
        self._pending_in = max(self._pending_in, 1)

    def take_degraded(self) -> set:
        """Blocks whose swap-in upload failed (garbage rows), cleared on
        read — the engine preempts their owners (recompute is bit-safe)."""
        bad, self._degraded_blocks = self._degraded_blocks, set()
        return bad

    def _block_rows(self, b: int) -> slice:
        return slice(b * self.block_size, (b + 1) * self.block_size)

    # -- allocator (O(1): deque pop/push + set membership + refcounts) ------
    @property
    def num_free(self) -> int:
        """Blocks reclaimable right now (refcount 0 — cached content, if
        any, is evicted the moment ``alloc()`` reclaims them)."""
        return len(self._free_set)

    def refcount(self, b: int) -> int:
        return self._ref[b]

    def alloc(self) -> int:
        """Claim a free block (refcount 0 -> 1).  Reclaims in least-recently-
        freed order; a reclaimed block's prefix-index entry is dropped — or,
        with a host tier attached and PREFILL provenance (see
        ``_decode_written``), SPILLED: the content and index entry move
        down to host RAM (swap, don't recompute) so a later admission can
        still match the prefix and stream it back in."""
        while self._free:
            b, epoch = self._free.popleft()
            if b not in self._free_set or epoch != self._free_epoch[b]:
                continue          # stale: revived by share(), or re-freed
                #                   later (a newer entry sits deeper in the
                #                   deque at its true eviction position)
            self._free_set.discard(b)
            key = self._block_key.pop(b, None)
            tainted = b in self._decode_written
            self._decode_written.discard(b)   # content dies with the reclaim
            if key is not None:
                del self._index[key]
                if self.host is not None and not tainted:
                    # spill through the draining getter: if this block is
                    # itself an unscattered swap-in target, its rows land
                    # first; the slices are immutable jax arrays, so the
                    # async device_get reads a true snapshot even after
                    # the new owner overwrites the pool
                    rows = self._block_rows(b)
                    pk = self.pool_k[:, rows]
                    pv = self.pool_v[:, rows]
                    # the getter read is a degradation barrier — re-check
                    # the tier survived it before spilling; a failed submit
                    # just skips the spill (content dropped, tier-off
                    # behavior) and degrades at the next barrier
                    if self.host is not None:
                        try:
                            self.host.put(key, pk, pv)
                        except SwapWorkerError as e:
                            self._host_failure(e)
            self._ref[b] = 1
            return b
        from repro.serve.scheduler import OutOfBlocksError

        raise OutOfBlocksError(
            f"KV pool exhausted ({self.num_blocks} blocks of "
            f"{self.block_size} tokens)")

    def share(self, b: int) -> None:
        """Take one more reference on a resident block (prefix-cache hit).
        A refcount-0 block is revived out of the free structure — its deque
        entry goes stale and is skipped lazily by ``alloc()``."""
        assert 0 <= b < self.num_blocks, b
        if self._ref[b] == 0:
            assert b in self._free_set, b
            self._free_set.discard(b)
        self._ref[b] += 1

    def mark_decode_write(self, b: int) -> None:
        """Record that a decode step wrote a row into block ``b`` — the
        engine calls this per decode token.  Taints the block against host
        spill (its bytes are no longer prefill-reproducible); cleared when
        ``alloc()`` reclaims the block and its content dies."""
        if 0 <= b < self.num_blocks:      # null-block writes don't taint
            self._decode_written.add(b)

    def free(self, blocks) -> None:
        """Drop one reference per block; a block becomes reclaimable (and
        evictable) only when its count reaches zero.  Content and index
        entry are RETAINED so a later admission can still match it."""
        for b in blocks:
            assert 0 <= b < self.num_blocks and self._ref[b] > 0, b
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free_epoch[b] += 1      # invalidate any stale entry
                self._free.append((b, self._free_epoch[b]))
                self._free_set.add(b)

    # -- prefix index -------------------------------------------------------
    def lookup(self, key: bytes) -> int | None:
        """Block caching exactly this token prefix, or None.  Any hit is
        valid to ``share()``: reclaiming is the only way content dies, and
        reclaiming removes the entry."""
        return self._index.get(key)

    def register(self, key: bytes, b: int) -> None:
        """Index a FULL block under its prefix key.  First writer wins: a
        duplicate key means another slot already caches identical content
        (same tokens, same weights), so the extra copy stays unindexed.
        A host-resident copy of the same key is dropped — the device tier
        is the faster home and a key lives in exactly one tier."""
        if key in self._index:
            return
        old = self._block_key.get(b)
        assert old is None or old == key, (b, old, key)
        if self.host is not None:
            self.host.invalidate(key)
        self._index[key] = b
        self._block_key[b] = key

    # -- host tier ----------------------------------------------------------
    def lookup_host(self, key: bytes) -> int | None:
        """Host slot caching exactly this prefix (the tiered index's second
        level), or None.  A hit is claimed with ``swap_in``."""
        if self.host is None:
            return None
        return self.host.lookup(key)

    def swap_in(self, key: bytes, into: int | None = None) -> int | None:
        """Stream ``key``'s host-resident block back into the device pool
        (async device_put; the next pool read is the drain point).  Claims
        the host content FIRST — before allocating, whose spill could
        otherwise evict the very block being swapped in — then lands it in
        a fresh device block, or in ``into`` (an unwritten block the caller
        already owns, the rematch upgrade path).  Registers the key at its
        new device home.  Returns the device block, or None when the host
        copy was evicted between match and claim (caller falls back to
        recompute for this and deeper blocks)."""
        host = self.host
        if host is None:
            return None
        try:
            stage = host.take(key)
        except SwapWorkerError as e:
            # take()'s drain tripped on a worker failure: fall back to
            # recompute for this block (the caller's None path) and degrade
            # at the next pool-read barrier
            self._host_failure(e)
            return None
        if stage is None:
            return None
        b = self.alloc() if into is None else into
        if self.host is None:
            # alloc()'s pool read degraded the tier under us: the staging
            # buffer was never submitted, the fresh block was never written
            host.swap.release_stage(stage)
            if into is None:
                self.free([b])
            return None
        bs = self.block_size
        flat = jnp.asarray(np.arange(b * bs, (b + 1) * bs, dtype=np.int32))
        try:
            host.swap.submit_in(flat, stage)
        except SwapWorkerError as e:
            host.swap.release_stage(stage)
            if into is None:
                self.free([b])
            self._host_failure(e)
            return None
        self._pending_in += 1
        self.register(key, b)
        host.metrics.inc("serve.swap.in_blocks")
        host.metrics.inc("serve.swap.in_bytes", host.block_bytes)
        return b

    def flush_index(self) -> None:
        """Forget every cached prefix in BOTH tiers (weights changed;
        allocations keep running on their own rows but are never matched
        again; in-flight swap-ins still land — they belong to running
        requests admitted before the flush)."""
        self._index.clear()
        self._block_key.clear()
        if self.host is not None:
            try:
                self.host.flush()
            except SwapWorkerError as e:
                self._host_failure(e)

    def reset(self) -> None:
        self._ref = [0] * self.num_blocks
        self._free_epoch = [0] * self.num_blocks
        self._free = deque((b, 0) for b in range(self.num_blocks))
        self._free_set = set(range(self.num_blocks))
        self._decode_written.clear()
        self.flush_index()
        if self.host is not None:
            self.host.swap.pop_ready()    # zeroing below discards them anyway
        self._pending_in = 0
        self._pool_k = jnp.zeros_like(self._pool_k)
        self._pool_v = jnp.zeros_like(self._pool_v)

    # -- views --------------------------------------------------------------
    def dense_view(self, tables) -> dict:
        """Dense {"k", "v"} cache for the given block tables (host or device)."""
        return gather_kv(self.pool_k, self.pool_v,
                         jnp.asarray(tables, jnp.int32), self.block_size)
