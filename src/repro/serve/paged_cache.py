"""Block-table paged KV cache (the serving-side memory manager).

The synchronized ``RolloutEngine`` allocates a dense ``(B, capacity)`` cache:
every sequence owns ``capacity`` slots for its whole life, which is exactly
the KV memory waste the paper's allgather-swap work fights on the weight
side.  Here KV lives in fixed-size BLOCKS:

  pool_k / pool_v : (num_layers, (num_blocks + 1) * block_size, kv, hd)

i.e. a flat row pool; block ``i`` owns rows ``[i*bs, (i+1)*bs)``.  The LAST
block is the **null block**: unassigned block-table entries point there, so
KV writes from idle serving slots land in it and reads of it are masked by
the attention validity mask — no per-slot branching inside the jitted step.

A slot's logical cache is described by one row of a block table
``(max_slots, max_blocks_per_seq) int32``; logical position ``j`` lives at
flat row ``table[j // bs] * bs + j % bs``.

The serving DECODE path never materializes a dense per-slot view: attention
reads the block tables directly (kernels/paged_attention.py — flash-decoding
Pallas kernel on TPU, chunked bitwise-exact jnp reference elsewhere), so the
paged cache is a speed win as well as a memory win — decode-step cost scales
with live tokens, not ``max_blocks_per_seq``.  ``gather_kv`` (Pallas
block-read kernel + advanced-index reference) survives only behind
``dense_view()`` as a debugging aid and the bit-compatibility oracle the
paged kernels are tested against.

Blocks are REF-COUNTED and PREFIX-INDEXED (vLLM's prefix caching, on the
paper's observation that GRPO's sample flow is maximally redundant at
admission — every group of N rollouts re-prefills the same prompt, and every
partial-rollout resume re-prefills a prefix that did not change):

  * ``alloc()`` hands out a block with refcount 1; ``share()`` takes an extra
    reference on a resident block (a prefix-cache hit); ``free()`` only
    DECREMENTS — a block returns to the free structure when its refcount
    hits zero, so N requests can read one prompt-head block concurrently.
  * ``register(key, block)`` indexes a FULL block under a chained hash of
    the entire token prefix it caches (``prefix_key``: H(parent_key ||
    block tokens), O(block) per extension); ``lookup(key)`` is how the
    scheduler matches a new request's block-aligned prompt head against
    resident blocks at admission.
  * A freed block KEEPS its content and index entry (it may be revived by a
    later ``share()``); the entry is dropped only when ``alloc()`` actually
    reclaims the block.  Eviction order is least-recently-freed first: the
    free structure is a ``deque`` (append on free, pop-left on reclaim)
    mirrored by a set — revival just removes the set entry and ``alloc()``
    skips the stale deque entry lazily, keeping every operation O(1).
  * ``flush_index()`` drops ALL index entries (allocations untouched) — the
    engine calls it when the policy weights change, because cached KV from
    the previous weights must never satisfy a prefix match under the new
    ones (partial rollout accepts a mildly off-policy RESUME, not silently
    stale KV).
"""
from __future__ import annotations

import functools
import hashlib
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L


def blocks_for(ntokens: int, block_size: int) -> int:
    return -(-ntokens // block_size)


def prefix_key(parent: bytes, block_tokens) -> bytes:
    """Chained per-block index key: H(parent_key || this block's token
    bytes) — vLLM's prefix-hash design.  The chain makes each key O(block)
    to extend (walking a stream's blocks is O(stream) total, and memoizable
    per request) while still identifying the ENTIRE prefix; 16-byte blake2b
    digests make collisions a non-concern next to f32 rollout numerics.
    The root block's parent is ``b""``."""
    h = hashlib.blake2b(parent, digest_size=16)
    h.update(np.ascontiguousarray(block_tokens, dtype=np.int32).tobytes())
    return h.digest()


# ---------------------------------------------------------------------------
# gather: pool rows -> dense per-slot view
# ---------------------------------------------------------------------------

def flat_indices(tables: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """tables: (S, MB) int32 -> flat pool row per (slot, logical pos):
    (S, MB * block_size) int32."""
    cap = tables.shape[1] * block_size
    j = jnp.arange(cap, dtype=jnp.int32)
    return tables[:, j // block_size] * block_size + j % block_size


def gather_pool_ref(pool: jnp.ndarray, tables: jnp.ndarray,
                    block_size: int) -> jnp.ndarray:
    """pool: (n, R, kv, hd); tables: (S, MB) -> (n, S, MB*bs, kv, hd)."""
    return pool[:, flat_indices(tables, block_size)]


def _gather_block_kernel(tbl_ref, pool_ref, o_ref):
    del tbl_ref  # consumed by the index maps (scalar prefetch)
    o_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def gather_pool_pallas(pool: jnp.ndarray, tables: jnp.ndarray,
                       block_size: int, interpret: bool = False) -> jnp.ndarray:
    """Pallas block-read kernel: grid (layer, slot, block); the block table is
    a scalar-prefetch operand so each program DMAs exactly the pool block its
    table entry names (vLLM's paged attention gather, at the memory level)."""
    from jax.experimental.pallas import tpu as pltpu

    n, rows, kv, hd = pool.shape
    s, mb = tables.shape
    k = kv * hd
    pool4 = pool.reshape(n, rows // block_size, block_size, k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, s, mb),
        in_specs=[pl.BlockSpec((1, 1, block_size, k),
                               lambda l, i, j, tbl: (l, tbl[i, j], 0, 0))],
        out_specs=pl.BlockSpec((1, 1, block_size, k),
                               lambda l, i, j, tbl: (l, i, j, 0)),
    )
    out = pl.pallas_call(
        _gather_block_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, s, mb * block_size, k), pool.dtype),
        interpret=interpret,
    )(tables, pool4)
    return out.reshape(n, s, mb * block_size, kv, hd)


def gather_kv(pool_k: jnp.ndarray, pool_v: jnp.ndarray, tables: jnp.ndarray,
              block_size: int) -> dict:
    """Dense {"k", "v"} view of the paged pools — the cache pytree the
    model-zoo ``decode`` consumes.  Dispatches like kernels/ops.py: Pallas on
    TPU (or REPRO_PALLAS=interpret), jnp reference elsewhere."""
    if ops._use_pallas():
        interp = not jax.default_backend() == "tpu"
        return {"k": gather_pool_pallas(pool_k, tables, block_size, interp),
                "v": gather_pool_pallas(pool_v, tables, block_size, interp)}
    return {"k": gather_pool_ref(pool_k, tables, block_size),
            "v": gather_pool_ref(pool_v, tables, block_size)}


# ---------------------------------------------------------------------------
# scatter: step / prefill writes into the pool
# ---------------------------------------------------------------------------

def scatter_token(pool: jnp.ndarray, rows: jnp.ndarray,
                  flat_pos: jnp.ndarray) -> jnp.ndarray:
    """Write one decode step's KV.  rows: (n, S, kv, hd); flat_pos: (S,) —
    idle slots' tables route their write to the null block."""
    return pool.at[:, flat_pos].set(rows)


def scatter_prefill(pool: jnp.ndarray, rows: jnp.ndarray,
                    flat_rows: jnp.ndarray) -> jnp.ndarray:
    """Write one sequence's prefill KV.  rows: (n, P, kv, hd); flat_rows: (P,)."""
    return pool.at[:, flat_rows].set(rows)


# ---------------------------------------------------------------------------
# the cache object (pool arrays + block allocator)
# ---------------------------------------------------------------------------

class PagedKVCache:
    """Owns the block pools, the ref-counted free structure and the prefix
    index.  Layout-compatible with the transformer-family dense cache:
    gathering a slot's blocks reproduces the ``init_cache``/``prefill`` row
    content bit-for-bit, which is what makes ``ServingEngine.generate``
    bit-compatible with ``RolloutEngine``."""

    def __init__(self, cfg: ModelConfig, *, num_blocks: int, block_size: int,
                 max_blocks_per_seq: int):
        if cfg.num_kv_heads <= 0:
            raise ValueError(
                f"paged KV cache needs an attention cache; arch "
                f"{cfg.name!r} ({cfg.arch_type}) has no KV heads")
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.null_block = num_blocks          # last block = write sink
        n, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        rows = (num_blocks + 1) * block_size
        dt = L.cdtype(cfg)
        self.pool_k = jnp.zeros((n, rows, kv, hd), dt)
        self.pool_v = jnp.zeros((n, rows, kv, hd), dt)
        self._ref = [0] * num_blocks          # per-block reference counts
        # ref-0 blocks in eviction order (least-recently freed first).  The
        # deque holds (block, epoch) entries and may hold STALE ones for
        # blocks share() revived — each free() bumps the block's epoch, so
        # alloc() recognizes an entry as live only if it is the block's
        # NEWEST free (epoch match) and the block is still in the mirror
        # set.  That keeps eviction order exact under free/revive/free
        # churn while every operation stays O(1).
        self._free_epoch = [0] * num_blocks
        self._free = deque((b, 0) for b in range(num_blocks))
        self._free_set = set(range(num_blocks))
        self._index: dict[bytes, int] = {}    # prefix_key -> block
        self._block_key: dict[int, bytes] = {}  # block -> its index key

    # -- allocator (O(1): deque pop/push + set membership + refcounts) ------
    @property
    def num_free(self) -> int:
        """Blocks reclaimable right now (refcount 0 — cached content, if
        any, is evicted the moment ``alloc()`` reclaims them)."""
        return len(self._free_set)

    def refcount(self, b: int) -> int:
        return self._ref[b]

    def alloc(self) -> int:
        """Claim a free block (refcount 0 -> 1).  Reclaims in least-recently-
        freed order; a reclaimed block's prefix-index entry is dropped — its
        cached content is being overwritten."""
        while self._free:
            b, epoch = self._free.popleft()
            if b not in self._free_set or epoch != self._free_epoch[b]:
                continue          # stale: revived by share(), or re-freed
                #                   later (a newer entry sits deeper in the
                #                   deque at its true eviction position)
            self._free_set.discard(b)
            key = self._block_key.pop(b, None)
            if key is not None:
                del self._index[key]
            self._ref[b] = 1
            return b
        from repro.serve.scheduler import OutOfBlocksError

        raise OutOfBlocksError(
            f"KV pool exhausted ({self.num_blocks} blocks of "
            f"{self.block_size} tokens)")

    def share(self, b: int) -> None:
        """Take one more reference on a resident block (prefix-cache hit).
        A refcount-0 block is revived out of the free structure — its deque
        entry goes stale and is skipped lazily by ``alloc()``."""
        assert 0 <= b < self.num_blocks, b
        if self._ref[b] == 0:
            assert b in self._free_set, b
            self._free_set.discard(b)
        self._ref[b] += 1

    def free(self, blocks) -> None:
        """Drop one reference per block; a block becomes reclaimable (and
        evictable) only when its count reaches zero.  Content and index
        entry are RETAINED so a later admission can still match it."""
        for b in blocks:
            assert 0 <= b < self.num_blocks and self._ref[b] > 0, b
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free_epoch[b] += 1      # invalidate any stale entry
                self._free.append((b, self._free_epoch[b]))
                self._free_set.add(b)

    # -- prefix index -------------------------------------------------------
    def lookup(self, key: bytes) -> int | None:
        """Block caching exactly this token prefix, or None.  Any hit is
        valid to ``share()``: reclaiming is the only way content dies, and
        reclaiming removes the entry."""
        return self._index.get(key)

    def register(self, key: bytes, b: int) -> None:
        """Index a FULL block under its prefix key.  First writer wins: a
        duplicate key means another slot already caches identical content
        (same tokens, same weights), so the extra copy stays unindexed."""
        if key in self._index:
            return
        old = self._block_key.get(b)
        assert old is None or old == key, (b, old, key)
        self._index[key] = b
        self._block_key[b] = key

    def flush_index(self) -> None:
        """Forget every cached prefix (weights changed; allocations keep
        running on their own rows but are never matched again)."""
        self._index.clear()
        self._block_key.clear()

    def reset(self) -> None:
        self._ref = [0] * self.num_blocks
        self._free_epoch = [0] * self.num_blocks
        self._free = deque((b, 0) for b in range(self.num_blocks))
        self._free_set = set(range(self.num_blocks))
        self.flush_index()
        self.pool_k = jnp.zeros_like(self.pool_k)
        self.pool_v = jnp.zeros_like(self.pool_v)

    # -- views --------------------------------------------------------------
    def dense_view(self, tables) -> dict:
        """Dense {"k", "v"} cache for the given block tables (host or device)."""
        return gather_kv(self.pool_k, self.pool_v,
                         jnp.asarray(tables, jnp.int32), self.block_size)
