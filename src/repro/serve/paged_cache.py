"""Block-table paged KV cache (the serving-side memory manager).

The synchronized ``RolloutEngine`` allocates a dense ``(B, capacity)`` cache:
every sequence owns ``capacity`` slots for its whole life, which is exactly
the KV memory waste the paper's allgather-swap work fights on the weight
side.  Here KV lives in fixed-size BLOCKS:

  pool_k / pool_v : (num_layers, (num_blocks + 1) * block_size, kv, hd)

i.e. a flat row pool; block ``i`` owns rows ``[i*bs, (i+1)*bs)``.  The LAST
block is the **null block**: unassigned block-table entries point there, so
KV writes from idle serving slots land in it and reads of it are masked by
the attention validity mask — no per-slot branching inside the jitted step.

A slot's logical cache is described by one row of a block table
``(max_slots, max_blocks_per_seq) int32``; logical position ``j`` lives at
flat row ``table[j // bs] * bs + j % bs``.

The serving DECODE path never materializes a dense per-slot view: attention
reads the block tables directly (kernels/paged_attention.py — flash-decoding
Pallas kernel on TPU, chunked bitwise-exact jnp reference elsewhere), so the
paged cache is a speed win as well as a memory win — decode-step cost scales
with live tokens, not ``max_blocks_per_seq``.  ``gather_kv`` (Pallas
block-read kernel + advanced-index reference) survives only behind
``dense_view()`` as a debugging aid and the bit-compatibility oracle the
paged kernels are tested against.

The block allocator is O(1): a ``deque`` free list (FIFO, preserving the
historical allocation order) mirrored by a set for O(1) double-free checks.
"""
from __future__ import annotations

import functools
from collections import deque

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L


def blocks_for(ntokens: int, block_size: int) -> int:
    return -(-ntokens // block_size)


# ---------------------------------------------------------------------------
# gather: pool rows -> dense per-slot view
# ---------------------------------------------------------------------------

def flat_indices(tables: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """tables: (S, MB) int32 -> flat pool row per (slot, logical pos):
    (S, MB * block_size) int32."""
    cap = tables.shape[1] * block_size
    j = jnp.arange(cap, dtype=jnp.int32)
    return tables[:, j // block_size] * block_size + j % block_size


def gather_pool_ref(pool: jnp.ndarray, tables: jnp.ndarray,
                    block_size: int) -> jnp.ndarray:
    """pool: (n, R, kv, hd); tables: (S, MB) -> (n, S, MB*bs, kv, hd)."""
    return pool[:, flat_indices(tables, block_size)]


def _gather_block_kernel(tbl_ref, pool_ref, o_ref):
    del tbl_ref  # consumed by the index maps (scalar prefetch)
    o_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def gather_pool_pallas(pool: jnp.ndarray, tables: jnp.ndarray,
                       block_size: int, interpret: bool = False) -> jnp.ndarray:
    """Pallas block-read kernel: grid (layer, slot, block); the block table is
    a scalar-prefetch operand so each program DMAs exactly the pool block its
    table entry names (vLLM's paged attention gather, at the memory level)."""
    from jax.experimental.pallas import tpu as pltpu

    n, rows, kv, hd = pool.shape
    s, mb = tables.shape
    k = kv * hd
    pool4 = pool.reshape(n, rows // block_size, block_size, k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, s, mb),
        in_specs=[pl.BlockSpec((1, 1, block_size, k),
                               lambda l, i, j, tbl: (l, tbl[i, j], 0, 0))],
        out_specs=pl.BlockSpec((1, 1, block_size, k),
                               lambda l, i, j, tbl: (l, i, j, 0)),
    )
    out = pl.pallas_call(
        _gather_block_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, s, mb * block_size, k), pool.dtype),
        interpret=interpret,
    )(tables, pool4)
    return out.reshape(n, s, mb * block_size, kv, hd)


def gather_kv(pool_k: jnp.ndarray, pool_v: jnp.ndarray, tables: jnp.ndarray,
              block_size: int) -> dict:
    """Dense {"k", "v"} view of the paged pools — the cache pytree the
    model-zoo ``decode`` consumes.  Dispatches like kernels/ops.py: Pallas on
    TPU (or REPRO_PALLAS=interpret), jnp reference elsewhere."""
    if ops._use_pallas():
        interp = not jax.default_backend() == "tpu"
        return {"k": gather_pool_pallas(pool_k, tables, block_size, interp),
                "v": gather_pool_pallas(pool_v, tables, block_size, interp)}
    return {"k": gather_pool_ref(pool_k, tables, block_size),
            "v": gather_pool_ref(pool_v, tables, block_size)}


# ---------------------------------------------------------------------------
# scatter: step / prefill writes into the pool
# ---------------------------------------------------------------------------

def scatter_token(pool: jnp.ndarray, rows: jnp.ndarray,
                  flat_pos: jnp.ndarray) -> jnp.ndarray:
    """Write one decode step's KV.  rows: (n, S, kv, hd); flat_pos: (S,) —
    idle slots' tables route their write to the null block."""
    return pool.at[:, flat_pos].set(rows)


def scatter_prefill(pool: jnp.ndarray, rows: jnp.ndarray,
                    flat_rows: jnp.ndarray) -> jnp.ndarray:
    """Write one sequence's prefill KV.  rows: (n, P, kv, hd); flat_rows: (P,)."""
    return pool.at[:, flat_rows].set(rows)


# ---------------------------------------------------------------------------
# the cache object (pool arrays + block allocator)
# ---------------------------------------------------------------------------

class PagedKVCache:
    """Owns the block pools and the free list.  Layout-compatible with the
    transformer-family dense cache: gathering a slot's blocks reproduces the
    ``init_cache``/``prefill`` row content bit-for-bit, which is what makes
    ``ServingEngine.generate`` bit-compatible with ``RolloutEngine``."""

    def __init__(self, cfg: ModelConfig, *, num_blocks: int, block_size: int,
                 max_blocks_per_seq: int):
        if cfg.num_kv_heads <= 0:
            raise ValueError(
                f"paged KV cache needs an attention cache; arch "
                f"{cfg.name!r} ({cfg.arch_type}) has no KV heads")
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.null_block = num_blocks          # last block = write sink
        n, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        rows = (num_blocks + 1) * block_size
        dt = L.cdtype(cfg)
        self.pool_k = jnp.zeros((n, rows, kv, hd), dt)
        self.pool_v = jnp.zeros((n, rows, kv, hd), dt)
        self._free = deque(range(num_blocks))
        self._free_set = set(self._free)

    # -- allocator (O(1): deque pop/push + set membership) ------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        if not self._free:
            from repro.serve.scheduler import OutOfBlocksError

            raise OutOfBlocksError(
                f"KV pool exhausted ({self.num_blocks} blocks of "
                f"{self.block_size} tokens)")
        b = self._free.popleft()
        self._free_set.discard(b)
        return b

    def free(self, blocks) -> None:
        for b in blocks:
            assert 0 <= b < self.num_blocks and b not in self._free_set, b
            self._free.append(b)
            self._free_set.add(b)

    def reset(self) -> None:
        self._free = deque(range(self.num_blocks))
        self._free_set = set(self._free)
        self.pool_k = jnp.zeros_like(self.pool_k)
        self.pool_v = jnp.zeros_like(self.pool_v)

    # -- views --------------------------------------------------------------
    def dense_view(self, tables) -> dict:
        """Dense {"k", "v"} cache for the given block tables (host or device)."""
        return gather_kv(self.pool_k, self.pool_v,
                         jnp.asarray(tables, jnp.int32), self.block_size)
