"""ServingEngine — request-level continuous batching over the paged KV cache.

Two APIs over one machinery:

  * online  — ``submit(prompt)`` / ``step(params)`` / ``drain(params)``: a
    request loop.  Each ``step`` admits whatever fits (prefill + KV inject),
    runs ONE fused decode step over the whole slot batch, and evicts finished
    sequences immediately — freed slots refill next step, so short requests
    never wait for long ones.  A request may be submitted MID-SEQUENCE
    (``generated=`` carries tokens from earlier runs; admission re-prefills
    prompt+seed exactly like a recompute-preemption refill) and carry a
    per-run ``budget``; ``run_to_budget(params)`` drains the queue and
    returns budget-exhausted requests as RESUMABLE — this is what backs
    cross-iteration partial rollout (core/partial.py).
  * batch   — ``generate(params, prompts, key)``: drop-in for
    ``core.rollout.RolloutEngine.generate``.  All prompts are prefilled in a
    single jitted call (bit-identical to the synchronized engine) and their
    KV rows injected at admission; with ``max_slots >= B`` and a block-aligned
    capacity the outputs are BIT-compatible with ``RolloutEngine`` under
    greedy decoding (tested).  ``on_finish`` streams each sample out the
    moment it completes — the trainer uses it to push finished rollouts into
    the transfer dock before the batch barrier.

The decode batch is always the full ``(max_slots,)`` slot vector: idle slots
carry the pad token, position 0, and a block table pointing at the null
block, so jitted shapes never change and no recompilation happens as
sequences come and go.  Per-slot depths ride the model zoo's paged decode
path (``decode_paged`` in models/transformer.py, models/moe.py), whose
attention reads the block tables DIRECTLY (kernels/paged_attention.py on
TPU, the chunked jnp reference elsewhere) — no dense per-slot cache view is
gathered, so decode-step cost scales with live tokens, not pool capacity.

Admission is PREFIX-CACHED and (optionally) CHUNKED:

  * ``prefix_cache=True`` (default) — the scheduler matches each request's
    block-aligned prompt head against resident ref-counted blocks
    (serve/paged_cache.py) and only the divergent tail is prefilled via the
    model zoo's ``prefill_paged`` continuation entry; GRPO's N-per-prompt
    groups prefill the prompt once, and preemption/partial-rollout resumes
    re-match their own still-indexed blocks.  A NEW params object flushes
    the index — stale-weights KV is never matched.
  * ``prefill_chunk=C`` — admission prefill is split into <=C-token chunks
    interleaved with decode steps: each ``step()`` spends at most C prefill
    tokens total (``max_step_prefill`` tracks the observed maximum), so a
    max-length prompt admitted mid-decode never monopolizes a step.
    Mid-prefill slots ride the fused decode step as idle (tables masked to
    the null block) until their first token is sampled.
  * ``host_tier_blocks=N`` — attaches a host-RAM KV tier beneath the
    device pool (serve/host_tier.py): reclaiming an indexed
    prefill-provenance block SPILLS it to host instead of dropping it, the
    scheduler matches host-resident prefixes at admission, and re-admission
    streams them back (swap preemption instead of recompute preemption).
    Requires ``prefix_cache=True`` — the tier is the index's second level.

Bit-identity scope (stated precisely, because the suite enforces it):
``generate()``'s batch path keeps its bitwise contract with
``RolloutEngine`` (incl. gen_logp) at ANY capacity — stash admissions
inject the one batched prefill's rows, and a prefix match only elides
writing identical bits.  The ONLINE path (submit/step, and generate()'s
preemption refills) is bitwise invariant to sharing, chunk size, and the
host tier being on or off, while the pow2-padded slot capacity fits one
flash kv-block (``REPRO_ATTN_BLOCK``, 512 rows — every test/smoke
config); past that the continuation chunk's online-softmax block
partition differs from whole-prompt prefill's, logits agree to allclose
rather than bitwise, and greedy equality is token-level in practice — the
same caveat the PR-4 bucketed admission prefill already carried versus
the sync engine.

SAMPLED decoding carries the same contract, because sampling is
COUNTER-BASED per request: ``submit`` derives each request's stream root
``fold_in(run_key, seed)`` (seed defaults to the rid) and token ``t`` is
drawn with ``fold_in(stream, t)`` — never from an engine-wide key chain —
so a request's sampled tokens are a pure function of (params, prompt,
stream, t), bitwise invariant to admission order, pool size, chunking,
preemption/refill, budget suspend/resume and the host tier, and equal to
the sync ``RolloutEngine`` wherever the logits themselves are bit-equal
(the flash kv-block scope above).  ``docs/serving.md`` § "Deterministic
sampling" states the full replay contract.

The tier-on/off leg additionally rests on three rules:
only prefill-provenance blocks spill (``PagedKVCache.mark_decode_write``),
a match chain never continues through device blocks after a host hit
(``Scheduler._match``), and swap-in registration lands at admission like
a whole-tail recompute's — so prefer unchunked admission when exact
tier-on/off logp equality matters.  See docs/serving.md.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.rollout import (RolloutResult, request_stream, sample_tokens,
                                sampled_drawer)
from repro.models.model import build_model
from repro.obs import MetricsRegistry, get_tracer
from repro.serve.host_tier import HostKVTier, SwapWorkerError
from repro.serve.paged_cache import (PagedKVCache, blocks_for,
                                     scatter_prefill, scatter_token)
from repro.serve.scheduler import Request, Scheduler


def prefill_bucket(n: int) -> int:
    """Admission-prefill length bucket: next power of two (>= 8).  Online
    ``submit()`` sees arbitrary prompt+seed lengths; bucketing bounds the
    number of prefill/scatter jit specializations at O(log max_len) instead
    of one per distinct length."""
    b = 8
    while b < n:
        b *= 2
    return b


@dataclass
class RequestOutput:
    rid: int
    prompt: np.ndarray       # (P,)  int32
    gen: np.ndarray          # (n,)  int32 — generated tokens, EOS inclusive
    gen_logp: np.ndarray     # (n,)  float32 — engine-side logp per token
    latency_s: float         # submit -> finish
    ttft_s: float            # submit -> first token (prefill)
    preemptions: int

    @property
    def tokens(self) -> np.ndarray:
        return np.concatenate([self.prompt, self.gen])


class ServingEngine:
    """Continuous-batching generation engine (the vLLM-Ascend analogue)."""

    def __init__(self, cfg: ModelConfig, *, max_new: int, eos_id: int,
                 pad_id: int, temperature: float = 1.0, greedy: bool = False,
                 top_p: float = 1.0, top_k: int = 0,
                 max_slots: int = 8, block_size: int = 16,
                 max_seq_len: int | None = None, num_blocks: int | None = None,
                 prefix_cache: bool = True, prefill_chunk: int | None = None,
                 host_tier_blocks: int = 0, seed: int = 0, tracer=None,
                 faults=None):
        if cfg.arch_type not in ("dense", "moe"):
            # ssm/hybrid cache recurrent state (nothing to page); vlm would
            # need per-request vision_embeds carried through preemption
            # refills (ROADMAP) — silently re-prefilling without them would
            # corrupt the vision-prefix KV, so refuse up front.
            raise ValueError(
                f"serving needs the paged {{k,v}} attention cache; arch "
                f"{cfg.name!r} ({cfg.arch_type}) is not servable — "
                f"use the synchronized RolloutEngine for it")
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_new = max_new
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.temperature = temperature
        self.greedy = greedy
        if not 0.0 < top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {top_p}")
        if top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {top_k}")
        self.top_p = top_p
        self.top_k = top_k
        self.max_slots = max_slots
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        if host_tier_blocks and not prefix_cache:
            raise ValueError(
                "host_tier_blocks requires prefix_cache=True: the host tier "
                "is the prefix index's second level — without the index "
                "there is nothing to spill under or match against")
        self.host_tier_blocks = host_tier_blocks
        self._num_blocks_req = num_blocks
        self.cache: PagedKVCache | None = None
        self.sched: Scheduler | None = None
        # run key for counter-based per-request sampling streams: NEVER
        # split/advanced (that was the old engine-wide key chain, whose
        # sequencing leaked scheduling into every request's samples) — each
        # request derives fold_in(run_key, seed) at submit and owns its
        # stream from then on
        self._run_key = jax.random.PRNGKey(seed)
        self._next_rid = 0
        self._on_finish = None
        self._resumable: list[Request] = []  # budget-exhausted, slot freed
        self._seen_params = None            # weights-era token: a new params
        #                                     object flushes the prefix index
        # telemetry (repro.obs): the registry is ALWAYS on (aggregate
        # counters/histograms — engine.stats() and the bench artifacts read
        # it); the tracer defaults to the disabled process tracer, whose
        # calls are no-ops in the hot loop.  Counter catalog (exact names
        # documented in docs/observability.md):
        #   serve.prefill_tokens = real tokens run through prefill COMPUTE
        #   (bucket pads excluded; the batch generate() path counts its full
        #   batched prefill — a hit there elides pool writes/blocks, not
        #   FLOPs); serve.shared_prefill_tokens = rows satisfied by a prefix
        #   match instead of a fresh prefill (compute savings on the online
        #   path, block/memory savings on the batch path)
        self.tracer = tracer if tracer is not None else get_tracer()
        self.metrics = MetricsRegistry()
        # the host tier outlives pool regrows (_ensure_state rebuilds the
        # cache; host entries are content-addressed by prefix key, so they
        # stay valid against any device pool shape)
        self.host_tier = (
            HostKVTier(cfg, num_blocks=host_tier_blocks,
                       block_size=block_size, metrics=self.metrics,
                       tracer=self.tracer, faults=faults)
            if host_tier_blocks else None)
        self._host_degraded = False       # swap worker failed: tier dropped,
        #                                   recompute-preemption mode
        self._step_prefill = 0
        if max_seq_len is not None:
            self._ensure_state(max_seq_len)
        self._prefill = jax.jit(self._prefill_impl)
        self._chunk = jax.jit(self._chunk_impl)
        self._sample = jax.jit(self._sample_impl)
        self._step = jax.jit(self._step_impl, donate_argnums=(1, 2))
        self._write = jax.jit(scatter_prefill, donate_argnums=(0,))
        # sampled draws go through the PROCESS-SHARED drawer (one compiled
        # function per sampling config, the same object RolloutEngine uses)
        # — engine-local jits could fuse the log_softmax differently and
        # drift logp by ulps, breaking the cross-engine bitwise contract
        self._draw = (None if greedy else
                      sampled_drawer(temperature, top_p, top_k, pad_id))

    # ------------------------------------------------------------------
    # state
    # ------------------------------------------------------------------
    def _ensure_state(self, max_seq: int) -> None:
        mb = blocks_for(max_seq, self.block_size)
        if self.cache is not None:
            if self.cache.max_blocks_per_seq >= mb:
                return
            if self.sched.running:
                # running sequences have KV rows in the pool — regrowing
                # would orphan them; queued-only is safe (blocks are only
                # allocated at admission)
                raise RuntimeError(
                    f"request needs {mb} blocks/seq but the pool was sized "
                    f"for {self.cache.max_blocks_per_seq} and sequences are "
                    f"mid-decode; construct the engine with max_seq_len>= "
                    f"{max_seq} for mixed loads")
        waiting = self.sched.waiting if self.sched is not None else ()
        if (self.cache is not None and self.host_tier is not None
                and not self._host_degraded):
            # regrow drops the old pool; any in-flight swap-in targeted its
            # rows, so retire those (the owning requests were preempted —
            # they re-prefill; host entries themselves are content-addressed
            # and survive the regrow)
            try:
                self.host_tier.swap.drain()
                self.host_tier.swap.pop_ready()
            except SwapWorkerError:
                # the old pool is being dropped anyway, so no garbage rows
                # can survive — just flip to recompute-preemption mode
                self.host_tier.disable()
                self.metrics.inc("serve.swap.degraded")
                self._host_degraded = True
        num_blocks = self._num_blocks_req or self.max_slots * mb
        self.cache = PagedKVCache(self.cfg, num_blocks=num_blocks,
                                  block_size=self.block_size,
                                  max_blocks_per_seq=mb,
                                  host=(None if self._host_degraded
                                        else self.host_tier))
        self.sched = Scheduler(self.cache, self.max_slots,
                               prefix_cache=self.prefix_cache,
                               tracer=self.tracer, metrics=self.metrics)
        self.sched.waiting.extend(waiting)

    # ------------------------------------------------------------------
    # telemetry views (registry-backed; names in docs/observability.md)
    # ------------------------------------------------------------------
    @property
    def steps(self) -> int:
        """Fused decode steps run."""
        return self.metrics.value("serve.steps")

    @property
    def prefill_tokens(self) -> int:
        return self.metrics.value("serve.prefill_tokens")

    @property
    def shared_prefill_tokens(self) -> int:
        return self.metrics.value("serve.shared_prefill_tokens")

    @property
    def max_step_prefill(self) -> int:
        """Most prefill tokens any single step spent (chunk-budget bound)."""
        return int(self.metrics.value("serve.max_step_prefill"))

    def stats(self) -> dict:
        """Aggregate serving summary: request counts, token counters, and
        nearest-rank percentile summaries of per-request TTFT (submit ->
        first token) and e2e latency (submit -> finish), both derived from
        the ``Request`` ``submitted_at``/``first_token_at``/``finished_at``
        perf-counter stamps at finish time.  THE latency summary — consumers
        (examples/serve.py, bench artifacts) read this instead of computing
        their own percentiles."""
        m = self.metrics
        return {
            "submitted": m.value("serve.submitted"),
            "finished": m.value("serve.finished"),
            "suspended": m.value("serve.suspended"),
            "preemptions": m.value("serve.preemptions"),
            "preempt_swap": m.value("serve.preempt.swap"),
            "preempt_recompute": m.value("serve.preempt.recompute"),
            "steps": m.value("serve.steps"),
            "prefill_tokens": m.value("serve.prefill_tokens"),
            "shared_prefill_tokens": m.value("serve.shared_prefill_tokens"),
            "readmit_prefill_tokens": m.value("serve.readmit_prefill_tokens"),
            "decode_tokens": m.value("serve.decode_tokens"),
            "sampled_requests": m.value("serve.sampled.requests"),
            "sampled_tokens": m.value("serve.sampled.tokens"),
            "priority_bypass": m.value("serve.priority.bypass"),
            "max_step_prefill": int(m.value("serve.max_step_prefill")),
            "swap_out_blocks": m.value("serve.swap.out_blocks"),
            "swap_out_bytes": m.value("serve.swap.out_bytes"),
            "swap_in_blocks": m.value("serve.swap.in_blocks"),
            "swap_in_bytes": m.value("serve.swap.in_bytes"),
            "swap_host_evictions": m.value("serve.swap.host_evictions"),
            "swap_degraded": m.value("serve.swap.degraded"),
            "host_tier_blocks": self.host_tier_blocks,
            "host_resident_blocks": (len(self.host_tier)
                                     if self.host_tier else 0),
            "ttft_s": m.summarize("serve.ttft_s"),
            "latency_s": m.summarize("serve.latency_s"),
        }

    # ------------------------------------------------------------------
    # jitted pieces
    # ------------------------------------------------------------------
    def _prefill_impl(self, params, batch, last=None):
        """``last`` (traced () int32) selects the logits position for
        bucket-padded admission prefills; None (the batch generate() path)
        keeps the final position, bit-identical to RolloutEngine."""
        b, s = batch["tokens"].shape
        cache = self.model.init_cache(self.cfg, b, s)
        return self.model.prefill(params, self.cfg, batch, cache, last=last)

    def _sample_impl(self, logits):
        """GREEDY first-token sampling (argmax consumes no key; the graph is
        the pre-streams one, keeping greedy bit-contracts untouched).
        Sampled engines draw first tokens through ``self._draw`` instead."""
        return sample_tokens(logits, None, temperature=self.temperature,
                             greedy=True)

    def _chunk_impl(self, params, pool_k, pool_v, table, chunk, start, last):
        """One continuation-prefill chunk for one slot (see
        ``models.*.prefill_paged``).  Compiles once per chunk BUCKET
        (``prefill_bucket``), like the whole-prompt admission path."""
        return self.model.prefill_paged(params, self.cfg, pool_k, pool_v,
                                        table, chunk, start,
                                        block_size=self.block_size, last=last)

    def _step_impl(self, params, pool_k, pool_v, tables, tok, pos, done):
        """One continuous-batching decode step over the full slot batch.

        tables: (S, MB) int32; tok: (S, 1); pos: (S,) — per-slot write
        position (= current cache length); done: (S,) True on idle slots.
        GREEDY engines sample fused in this graph (argmax — the pre-streams
        graph, so greedy bit-contracts are untouched) and return
        ``(pool_k, pool_v, nxt, lp)``.  SAMPLED engines return
        ``(pool_k, pool_v, logits)``: the draw happens in the
        process-shared ``sampled_drawer`` with each slot's stream root and
        token count, so slot s's token depends only on its OWN stream and
        logits, never on which other requests share the step — and the
        draw compiles identically to the sync engine's.

        TRUE paged decode: attention reads the block tables directly
        (kernels/paged_attention.py + kernels/ref.py) and the model returns
        only this token's per-layer KV rows, which are scattered into the
        pool — no dense ``(n, S, MB*bs, kv, hd)`` cache view is ever
        materialized and nothing is re-extracted from one, so step cost
        scales with LIVE tokens, not pool capacity.  ``gather_kv`` survives
        only behind ``PagedKVCache.dense_view`` for debugging/oracle use."""
        logits, new_k, new_v = self.model.decode_paged(
            params, self.cfg, pool_k, pool_v, tables, tok, pos,
            block_size=self.block_size)
        s = tables.shape[0]
        rows = jnp.arange(s)
        flat = (tables[rows, pos // self.block_size] * self.block_size
                + pos % self.block_size)            # (S,) — idle -> null block
        pool_k = scatter_token(pool_k, new_k, flat)
        pool_v = scatter_token(pool_v, new_v, flat)
        if self.greedy:
            nxt, lp = sample_tokens(logits, None,
                                    temperature=self.temperature,
                                    greedy=True, done=done,
                                    pad_id=self.pad_id)
            return pool_k, pool_v, nxt, lp
        return pool_k, pool_v, logits

    # ------------------------------------------------------------------
    # online API
    # ------------------------------------------------------------------
    def submit(self, prompt, *, max_new: int | None = None,
               budget: int | None = None, generated=None,
               seed: int | None = None, priority: int = 0) -> int:
        """Queue one request.  Returns its engine-assigned request id.

        ``max_new`` caps the NEW tokens this submission may emit (defaults to
        the engine-wide cap — never mutated per request).  ``generated``
        seeds the request mid-sequence with tokens from earlier runs; the
        admission prefill then covers prompt+seed, the same re-prefill the
        recompute preemption does.  ``budget`` (≤ max_new to matter) makes
        the request SUSPEND resumable after that many new tokens — collect
        it from ``run_to_budget``.

        ``seed`` names the request's SAMPLING STREAM: token ``t`` is drawn
        with ``fold_in(fold_in(run_key, seed), t)`` where ``t`` counts all
        generated tokens including the mid-sequence seed, so resubmitting a
        suspension with the SAME ``seed`` continues its stream exactly.
        Defaults to the request id — distinct per submission, replayable on
        a fresh engine built with the same engine ``seed`` because rids are
        assigned in submission order.  ``priority`` picks the admission/
        preemption class (higher runs first, evicted last; FIFO within a
        class, starvation-bounded — see serve/scheduler.AdmissionQueue);
        it never changes what any request GENERATES, only when.

        Admission prefill is BUCKETED: prompts are right-padded to the next
        power-of-2 length (causally inert) so varied-length online traffic
        compiles O(log max_len) prefill specializations, not one per
        distinct length."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        max_new = self.max_new if max_new is None else max_new
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if budget is not None and budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        gen = [int(t) for t in generated] if generated is not None else []
        self._ensure_state(len(prompt) + len(gen) + max_new)
        rid = self._next_rid
        self._next_rid += 1
        if seed is None:
            seed = rid
        # greedy decoding never consumes a key — skip the stream derivation
        # so the greedy hot path stays dispatch-free at submit
        stream = (None if self.greedy else
                  np.asarray(request_stream(self._run_key, seed), np.uint32))
        # seeded tokens carry no engine-side logp (they were sampled in an
        # earlier run, possibly under different weights) — pad with zeros to
        # keep generated/gen_logp aligned
        self.sched.submit(Request(rid=rid, prompt=prompt, max_new=max_new,
                                  budget=budget, priority=priority,
                                  seed=seed, stream=stream, generated=gen,
                                  gen_logp=[0.0] * len(gen),
                                  resume_base=len(gen)))
        self.metrics.inc("serve.submitted")
        if not self.greedy:
            self.metrics.inc("serve.sampled.requests")
        return rid

    def _first_sample(self, logits, req: Request) -> tuple[int, float]:
        """Draw ``req``'s next token from its (1, V) admission-prefill
        logits.  Sampled requests go through the process-shared drawer with
        key ``fold_in(stream, t)``, ``t`` = tokens already generated
        (mid-sequence seed included) — bitwise the draw the decode step
        would make for this request at the same logits, so admission-time
        first-token sampling and decode sampling are one stream arithmetic.
        Greedy requests use the engine's fused greedy sampler."""
        if req.stream is None:
            t0, l0 = self._sample(logits)
        else:
            t0, l0 = self._draw(
                logits, jnp.asarray(req.stream)[None],
                jnp.full((1,), len(req.generated), jnp.int32),
                jnp.zeros((1,), bool))
        return int(t0[0]), float(l0[0])

    def flush_prefix(self) -> None:
        """Drop every cached prefix now — BOTH tiers (the host tier flushes
        through ``PagedKVCache.flush_index``).  ``step()`` does this
        automatically when it sees a NEW params object; call it explicitly
        if you update weights by mutating the params container in place
        (object identity cannot see that)."""
        if self.sched is not None:
            self.sched.flush_prefix()
        self._seen_params = None

    def close(self) -> None:
        """Stop the host tier's swap worker (no-op without a tier).  The
        worker is a daemon thread, so this is for tidy tests and long-lived
        drivers that churn engines, not a correctness requirement."""
        if self.host_tier is not None:
            self.host_tier.close()

    @staticmethod
    def _prefilling(req: Request) -> bool:
        """True while an admitted request still owes tail-prefill rows (its
        first token is not sampled yet, so it cannot join the decode batch)."""
        return req.cache_len < req.prefill_len

    def step(self, params) -> list[RequestOutput]:
        """Admit what fits, advance chunked prefills within the per-step
        token budget, run one fused decode step over the decodable slots,
        evict what finished.  Mid-prefill slots ride along as idle (their
        table rows are masked to the null block for the decode write), so a
        long prompt never monopolizes a step.

        When the tracer is enabled, every step emits one ``serve.step`` span
        plus ``serve.tokens`` / ``serve.slots`` counter samples; disabled,
        this wrapper is a single predicate check on top of the hot loop."""
        tr = self.tracer
        if not tr.enabled:
            return self._step_once(params)
        m = self.metrics
        with tr.span("serve.step", cat="serve", args=(args := {})):
            finished = self._step_once(params)
            args.update({
                "step": m.value("serve.steps"),
                "live_slots": self.sched.num_running if self.sched else 0,
                "waiting": self.sched.num_pending if self.sched else 0,
                "prefill_tokens": self._step_prefill,
                "finished": len(finished)})
        tr.counter("serve.tokens",
                   {"prefill": m.value("serve.prefill_tokens"),
                    "shared_prefill": m.value("serve.shared_prefill_tokens"),
                    "decode": m.value("serve.decode_tokens")}, cat="serve")
        tr.counter("serve.slots",
                   {"running": self.sched.num_running if self.sched else 0,
                    "waiting": self.sched.num_pending if self.sched else 0,
                    "preemptions": m.value("serve.preemptions"),
                    "prefix_hit_rows": m.value(
                        "serve.shared_prefill_tokens")}, cat="serve")
        if self.host_tier is not None:
            tr.counter("serve.swap",
                       {"out_bytes": m.value("serve.swap.out_bytes"),
                        "in_bytes": m.value("serve.swap.in_bytes"),
                        "host_resident": len(self.host_tier)}, cat="serve")
        return finished

    def _step_once(self, params) -> list[RequestOutput]:
        finished: list[RequestOutput] = []
        if self.sched is None:
            return finished
        if params is not self._seen_params:
            # new weights: cached prefixes are stale — never match them.
            # Weights-era detection is OBJECT IDENTITY on the params pytree:
            # the trainers pass one stable object per era (jit updates
            # produce a fresh pytree), so this is exact for every in-repo
            # caller.  A driver that mutates the params container IN PLACE
            # must call flush_prefix() itself; one that rebuilds an equal
            # pytree every step merely flushes the cache into a no-op.
            if self._seen_params is not None:
                self.sched.flush_prefix()
            self._seen_params = params
        self._step_prefill = 0
        self._admit(params, finished)
        self._advance_prefills(params, finished)
        self.metrics.set_max("serve.max_step_prefill", self._step_prefill)
        preempted = self.sched.ensure_capacity()
        if preempted:
            self.metrics.inc("serve.preemptions", len(preempted))
        if self.host_tier is not None and not self._host_degraded:
            # force the swap drain barrier now — after all of this step's
            # swap traffic was scheduled, BEFORE decode reads the pools: a
            # worker failure degrades the tier here, and the victims are
            # preempted before any garbage swap-in row can reach compute
            _ = self.cache.pool_k
            if self.cache.degraded:
                self._handle_degradation()
        decodable = [slot for slot, req in self.sched.running.items()
                     if not self._prefilling(req)]
        if not decodable:
            return finished
        s = self.max_slots
        tok = np.full((s, 1), self.pad_id, np.int32)
        pos = np.zeros((s,), np.int32)
        done = np.ones((s,), bool)
        streams = np.zeros((s, 2), np.uint32)   # idle/greedy: inert zero key
        tcount = np.zeros((s,), np.int32)
        tables = self.sched.tables
        for slot, req in self.sched.running.items():
            if self._prefilling(req):
                # not decoding this step: route its KV write to the null
                # block (a real table row would let the pad-token write
                # clobber row 0 — possibly a SHARED prefix block)
                tables = tables.copy() if tables is self.sched.tables \
                    else tables
                tables[slot, :] = self.cache.null_block
                continue
            tok[slot, 0] = req.generated[-1]
            pos[slot] = req.cache_len
            done[slot] = False
            if req.stream is not None:
                streams[slot] = req.stream
                tcount[slot] = len(req.generated)
        out = self._step(
            params, self.cache.pool_k, self.cache.pool_v,
            jnp.asarray(tables), jnp.asarray(tok),
            jnp.asarray(pos), jnp.asarray(done))
        if self.greedy:
            pool_k, pool_v, nxt, lp = out
        else:
            pool_k, pool_v, logits = out
            nxt, lp = self._draw(logits, jnp.asarray(streams),
                                 jnp.asarray(tcount), jnp.asarray(done))
        self.cache.pool_k, self.cache.pool_v = pool_k, pool_v
        self.metrics.inc("serve.steps")
        self.metrics.inc("serve.decode_tokens", len(decodable))
        if not self.greedy:
            self.metrics.inc("serve.sampled.tokens", len(decodable))
        nxt = np.asarray(nxt)
        lp = np.asarray(lp)
        for slot in decodable:
            req = self.sched.running[slot]
            # the row just written lives in this block: taint it against
            # host spill (decode bytes are not prefill-reproducible)
            self.cache.mark_decode_write(int(
                self.sched.tables[slot, req.cache_len // self.block_size]))
            req.cache_len += 1
            req.generated.append(int(nxt[slot]))
            req.gen_logp.append(float(lp[slot]))
            if req.cache_len % self.block_size == 0:
                # a decode-filled block just completed: index it so a
                # budget-suspended resume (or identical sampled prefix)
                # re-matches instead of re-prefilling
                self.sched.register_prefix(req)
            self._retire(req, finished)
        return finished

    def _handle_degradation(self) -> None:
        """The swap worker failed and the cache detached the tier
        (``PagedKVCache._degrade_host``) — finish the flip to plain
        recompute-preemption mode.  Every running request owning a block
        whose swap-in never landed is preempted (youngest first, matching
        ``ensure_capacity``'s victim order): its rows are garbage, and
        recompute re-prefills them bit-identically, so greedy outputs stay
        bitwise equal to a fault-free (or tier-off) run."""
        self._host_degraded = True
        bad = self.cache.take_degraded()
        victims = []
        if bad:
            for slot in reversed(self.sched._admit_order):
                blocks = self.sched._blocks.get(slot)
                if blocks is not None and bad.intersection(blocks) \
                        and self.sched.running.get(slot) is not None:
                    victims.append(slot)
            for slot in victims:
                self.sched._preempt(slot)
            if victims:
                self.metrics.inc("serve.preemptions", len(victims))
        if self.tracer.enabled:
            self.tracer.instant("serve.swap.degraded", cat="serve", args={
                "bad_blocks": sorted(int(b) for b in bad),
                "preempted": len(victims)})

    def drain(self, params) -> list[RequestOutput]:
        """Run steps until every queued request has finished.  Budgeted
        requests are refused here: their suspensions would be silently
        stranded (this returns finished outputs only) — use
        ``run_to_budget``, which collects them."""
        if self.sched is not None and any(
                r.budget is not None
                for r in (*self.sched.waiting, *self.sched.running.values())):
            raise RuntimeError(
                "drain() would drop budget-suspended requests on the floor; "
                "collect them with run_to_budget()")
        return self._drain(params)

    def _drain(self, params) -> list[RequestOutput]:
        outs: list[RequestOutput] = []
        while self.sched is not None and not self.sched.idle:
            outs.extend(self.step(params))
        return outs

    def run_to_budget(self, params, on_finish=None
                      ) -> tuple[list[RequestOutput], list[Request]]:
        """Drain the queue, retiring every request either FINISHED (EOS, or
        ``max_new`` new tokens emitted) or RESUMABLE (its per-run ``budget``
        exhausted first).  Returns ``(finished, resumable)``.

        Resumable requests' slots and KV blocks are already freed; continue
        one next run with ``submit(req.prompt, generated=req.generated,
        max_new=remaining, budget=...)`` — the re-prefill then happens under
        whatever weights that run passes, which is exactly the mildly
        off-policy resume partial rollout accepts by design.

        ``on_finish(out: RequestOutput)`` fires per request the moment it
        truly finishes (never for suspensions) — the partial-rollout trainer
        streams rows into the transfer dock from it mid-drain."""
        if on_finish is not None:
            self._on_finish = on_finish
        try:
            outs = self._drain(params)
        finally:
            if on_finish is not None:
                self._on_finish = None
            # hand over (or, on an aborted drain, discard) this run's
            # suspensions — stale entries must never leak into a later run
            resumable, self._resumable = self._resumable, []
        return outs, resumable

    # ------------------------------------------------------------------
    # admission / eviction
    # ------------------------------------------------------------------
    def _admit(self, params, finished: list) -> None:
        """Admit queued requests ONE at a time, prefilling (or scheduling
        the chunked prefill of) each before the next is matched — that
        ordering is what lets the 2nd..Nth member of a GRPO group admitted
        in the same step share the 1st member's freshly registered head."""
        while True:
            admitted = self.sched.admit(limit=1)
            if not admitted:
                return
            req = admitted[0]
            matched = req.cache_len            # rows the prefix match covers
            self.metrics.inc("serve.shared_prefill_tokens", matched)
            if req.stash is not None:
                # batch generate() path: rows come from the one batched
                # prefill; matched rows are already resident (bitwise the
                # same values) so their writes sink into the null block.
                # The batched prefill computed ALL p tokens regardless of
                # the match, so the full p counts as prefill compute — on
                # this path a hit saves blocks (memory), not FLOPs.
                krows, vrows, tok0, lp0 = req.stash
                req.stash = None
                p = krows.shape[1]
                self.metrics.inc("serve.prefill_tokens", p)
                flat = self._write_rows(req.slot, 0, matched, p, p)
                self.cache.pool_k = self._write(self.cache.pool_k, krows, flat)
                self.cache.pool_v = self._write(self.cache.pool_v, vrows, flat)
                req.cache_len = p
                self.sched.register_prefix(req)
                self._first_token(req, tok0, lp0, finished)
            elif matched == 0 and self.prefill_chunk is None:
                # whole-prompt bucketed masked prefill: right-pad to the next
                # power-of-2 length (pads are causally inert — rows < p and
                # their KV are bit-identical to an unpadded prefill) and read
                # the logits at the last REAL position; pad rows scatter into
                # the null block (the write sink), so the whole admission
                # path compiles once per BUCKET, not once per prompt length.
                toks = req.refill_tokens
                p = len(toks)
                pb = prefill_bucket(p)
                padded = np.full((pb,), self.pad_id, np.int32)
                padded[:p] = toks
                logits, cache = self._prefill(
                    params, {"tokens": jnp.asarray(padded[None])},
                    jnp.int32(p - 1))
                krows, vrows = cache["k"][:, 0], cache["v"][:, 0]
                self.metrics.inc("serve.prefill_tokens", p)
                if req.preemptions:
                    # re-admission prefill: with a host tier most of these
                    # rows would have been swapped in instead — THE
                    # machine-readable recompute-vs-swap A/B quantity
                    self.metrics.inc("serve.readmit_prefill_tokens", p)
                self._step_prefill += p
                flat = self._write_rows(req.slot, 0, 0, p, pb)
                self.cache.pool_k = self._write(self.cache.pool_k, krows, flat)
                self.cache.pool_v = self._write(self.cache.pool_v, vrows, flat)
                req.cache_len = p
                self.sched.register_prefix(req)
                t0, l0 = self._first_sample(logits, req)
                self._first_token(req, t0, l0, finished)
            elif self.prefill_chunk is None:
                # prefix hit, unchunked: one continuation chunk covers the
                # whole divergent tail (>= 1 token by the match cap)
                self._run_chunk(params, req, req.prefill_len - matched,
                                finished)
            # else: chunked mode — _advance_prefills drives the tail (and,
            # for a fresh prompt, the whole prefill) under the per-step
            # token budget; the request sits admitted but not decodable

    def _advance_prefills(self, params, finished: list) -> None:
        """Chunked-prefill scheduler half-step: spend at most
        ``prefill_chunk`` prefill tokens across the mid-prefill slots
        (admission order), so prefill work per engine step is bounded and
        decode latency for running sequences stays flat."""
        if self.prefill_chunk is None:
            return
        budget = self.prefill_chunk
        for slot in list(self.sched._admit_order):
            if budget <= 0:
                return
            req = self.sched.running.get(slot)
            if req is None or not self._prefilling(req):
                continue
            take = min(budget, req.prefill_len - req.cache_len)
            budget -= self._run_chunk(params, req, take, finished)

    def _run_chunk(self, params, req: Request, take: int, finished: list
                   ) -> int:
        """One continuation-prefill call: rows [cache_len, cache_len+take)
        of ``req``'s stream, attending to everything already resident
        (shared prefix blocks and earlier chunks).  Completing the prefill
        samples the first token from the final chunk's logits.  Returns the
        prefill tokens actually spent (rematch may shrink the tail)."""
        self.metrics.inc("serve.shared_prefill_tokens",
                         self.sched.rematch(req))
        # pool reads are the swap-failure barrier: take them BEFORE building
        # the chunk, and if the tier degraded under them, resolve victims
        # first — this request itself may own a garbage swap-in block, in
        # which case it was just preempted and must not compute this chunk
        pool_k, pool_v = self.cache.pool_k, self.cache.pool_v
        if (self.host_tier is not None and not self._host_degraded
                and self.cache.degraded):
            self._handle_degradation()
            if self.sched.running.get(req.slot) is not req:
                return 0              # preempted: re-admitted via recompute
        take = min(take, req.prefill_len - req.cache_len)
        toks = req.refill_tokens
        start = req.cache_len
        cb = prefill_bucket(take)
        chunk = np.full((cb,), self.pad_id, np.int32)
        chunk[:take] = toks[start:start + take]
        logits, krows, vrows = self._chunk(
            params, pool_k, pool_v,
            jnp.asarray(self.sched.tables[req.slot]),
            jnp.asarray(chunk[None]), jnp.int32(start), jnp.int32(take - 1))
        flat = self._write_rows(req.slot, start, 0, take, cb)
        self.cache.pool_k = self._write(self.cache.pool_k, krows, flat)
        self.cache.pool_v = self._write(self.cache.pool_v, vrows, flat)
        req.cache_len = start + take
        self.metrics.inc("serve.prefill_tokens", take)
        if req.preemptions:
            self.metrics.inc("serve.readmit_prefill_tokens", take)
        self._step_prefill += take
        self.sched.register_prefix(req)
        if not self._prefilling(req):
            t0, l0 = self._first_sample(logits, req)
            self._first_token(req, t0, l0, finished)
        return take

    def _first_token(self, req: Request, tok0: int, lp0: float,
                     finished: list) -> None:
        if req.first_token_at < 0:
            req.first_token_at = time.perf_counter()
        req.generated.append(tok0)
        req.gen_logp.append(lp0)
        if not self.greedy:
            self.metrics.inc("serve.sampled.tokens")
        self._retire(req, finished)

    def _write_rows(self, slot: int, base: int, skip: int, take: int,
                    padded: int) -> jnp.ndarray:
        """Flat pool rows for a (bucket-padded) prefill write whose row j
        holds GLOBAL position base+j: rows skip <= j < take land at their
        table-mapped position; everything else — already-resident
        prefix-matched rows (j < skip) and bucket pads (j >= take) — sinks
        into the null block, whose reads are always masked.  One mapping
        for all three admission writes: whole-prompt (base=0, skip=0),
        stash (base=0, skip=matched), chunk (base=start, skip=0)."""
        tbl = self.sched.tables[slot]
        j = np.arange(padded)
        g = base + np.minimum(j, take - 1)
        real = tbl[g // self.block_size] * self.block_size \
            + g % self.block_size
        sink = self.cache.null_block * self.block_size + j % self.block_size
        return jnp.asarray(np.where((j >= skip) & (j < take), real, sink))

    def _retire(self, req: Request, finished: list) -> None:
        """Evict the request if its last token ended it: EOS or ``max_new``
        new tokens => finished; per-run ``budget`` reached => suspended
        (resumable).  ``max_new`` is checked first, so a budget larger than
        the remaining cap clamps itself."""
        if (req.generated[-1] == self.eos_id
                or req.num_new >= req.max_new):
            self._finish(req.slot, finished)
        elif req.budget is not None and req.num_new >= req.budget:
            self._resumable.append(self.sched.suspend(req.slot))
            self.metrics.inc("serve.suspended")

    def _finish(self, slot: int, finished: list) -> None:
        req = self.sched.finish(slot)
        out = RequestOutput(
            rid=req.rid, prompt=req.prompt,
            gen=np.asarray(req.generated, np.int32),
            gen_logp=np.asarray(req.gen_logp, np.float32),
            latency_s=req.finished_at - req.submitted_at,
            ttft_s=max(req.first_token_at - req.submitted_at, 0.0),
            preemptions=req.preemptions)
        self.metrics.inc("serve.finished")
        self.metrics.observe("serve.ttft_s", out.ttft_s)
        self.metrics.observe("serve.latency_s", out.latency_s)
        finished.append(out)
        if self._on_finish is not None:
            self._on_finish(out)

    # ------------------------------------------------------------------
    # batch API — drop-in for RolloutEngine.generate
    # ------------------------------------------------------------------
    def generate(self, params, prompts: np.ndarray, key, extras=None,
                 on_finish=None) -> RolloutResult:
        """prompts: (B, PL) int32 padded.  Continuous-batching decode; each
        finished sample is streamed to ``on_finish(i, tokens_row, mask_row,
        length)`` the moment it completes (cap-width rows, dock-ready).

        ``key`` is consumed as this CALL's run key only — row ``i`` samples
        token ``t`` with ``fold_in(fold_in(key, i), t)``, exactly
        ``RolloutEngine.generate``'s derivation, and NO engine state is
        mutated by it: the same (params, prompts, key) replays bitwise on
        this engine or a fresh one, and interleaved ``generate()`` calls
        never cross-contaminate."""
        b, pl = prompts.shape
        cap = pl + self.max_new
        self._ensure_state(cap)
        if not self.sched.idle:
            raise RuntimeError("generate() needs an idle engine")
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update(extras)
        # ONE batched prefill for the whole wave — bit-identical numerics to
        # RolloutEngine's prefill; rows are injected into the pool per slot
        # at admission time, so refills never recompile.
        logits, cache = self._prefill(params, batch)
        streams = np.asarray(
            jax.vmap(lambda i: request_stream(key, i))(jnp.arange(b)),
            np.uint32)
        if self.greedy:
            tok0, lp0 = self._sample(logits)
        else:
            tok0, lp0 = self._draw(logits, jnp.asarray(streams),
                                   jnp.zeros((b,), jnp.int32),
                                   jnp.zeros((b,), bool))
        tok0, lp0 = np.asarray(tok0), np.asarray(lp0)

        rows: dict[int, tuple] = {}

        def sink(out: RequestOutput):
            trow, mrow, n = self.assemble_row(out, pl, cap)
            rows[out.rid] = (trow, mrow, n, out)
            if on_finish is not None:
                on_finish(out.rid, trow, mrow, n)

        self._on_finish = sink
        try:
            for i in range(b):
                req = Request(rid=i, prompt=np.asarray(prompts[i], np.int32),
                              max_new=self.max_new, seed=i,
                              stream=None if self.greedy else streams[i])
                req.stash = (cache["k"][:, i], cache["v"][:, i],
                             int(tok0[i]), float(lp0[i]))
                self.sched.submit(req)
            self.drain(params)
        finally:
            self._on_finish = None

        t = max(r[2] for r in rows.values())
        tokens = np.stack([rows[i][0] for i in range(b)])
        mask = np.stack([rows[i][1] for i in range(b)])
        lengths = np.asarray([rows[i][2] for i in range(b)], np.int32)
        gen_logp = np.zeros((b, t), np.float32)
        for i in range(b):
            out = rows[i][3]
            gen_logp[i, :len(out.gen_logp)] = out.gen_logp
        return RolloutResult(tokens=tokens, response_mask=mask,
                             gen_logp=gen_logp, lengths=lengths)

    def assemble_row(self, out: RequestOutput, pl: int, cap: int):
        """RolloutEngine-format row: prompt + gen, PAD after EOS.  THE
        dock-ready row format — every consumer (generate()'s on_finish and
        the partial-rollout trainer's sink) assembles through here."""
        row = np.full((cap,), self.pad_id, np.int32)
        row[:pl] = out.prompt[:pl]
        n = len(out.gen)
        row[pl:pl + n] = out.gen
        mask = np.zeros((cap,), np.float32)
        mask[pl:pl + n] = 1.0
        return row, mask, n
