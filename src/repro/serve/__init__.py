"""Continuous-batching serving subsystem (the vLLM-Ascend analogue).

  * ``paged_cache``  — block-table paged KV cache over the model zoo's
    ``init_cache/prefill/decode`` API: ref-counted, prefix-indexed blocks
    (prompt-head sharing) with a Pallas gather kernel for block reads and a
    pure-JAX reference path.
  * ``host_tier``    — host-memory KV tier beneath the device pool:
    reclaimed-but-indexed blocks spill to host RAM through an async,
    double-buffered swap engine, and the prefix index spans both tiers —
    swap, don't recompute.
  * ``scheduler``    — request queue: prefix-matched admission (device OR
    host hits), slot assignment, EOS-driven eviction and refill, and
    swap- or recompute-preemption when blocks run out.
  * ``engine``       — ``ServingEngine``: online ``submit/step/drain`` (with
    mid-sequence submission, per-run budgets — ``run_to_budget`` hands
    budget-exhausted requests back resumable, the backend of partial
    rollout — and chunked prefill interleaved with decode) plus a
    ``generate()`` batch API that is a drop-in for ``core.rollout``'s
    ``RolloutEngine``.

See docs/serving.md for the block lifecycle and bit-identity contracts.
"""
from repro.serve.engine import RequestOutput, ServingEngine  # noqa: F401
from repro.serve.host_tier import HostKVTier, SwapEngine  # noqa: F401
from repro.serve.paged_cache import PagedKVCache  # noqa: F401
from repro.serve.scheduler import OutOfBlocksError, Request, Scheduler  # noqa: F401
