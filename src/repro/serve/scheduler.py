"""Continuous-batching request scheduler.

Requests queue FIFO and are admitted into one of ``max_slots`` serving slots
whenever a slot AND enough KV blocks for their prompt (+1 decode token) are
free.  A finished sequence (EOS or per-request token budget) is evicted the
moment it completes and its slot refilled from the queue — no batch barrier,
which is the whole point versus the synchronized ``RolloutEngine``.

When a running sequence needs a new block and the pool is dry, the scheduler
preempts the YOUNGEST running request (vLLM's recompute preemption): its
blocks are released, and the request re-queues at the FRONT with its
generated-so-far tokens folded into the prompt, to be re-prefilled on
re-admission.

The SAME re-prefill path serves cross-iteration partial rollout
(``core/partial.py``): a request may be submitted MID-SEQUENCE, seeded with
the tokens generated in earlier iterations (``generated`` +
``resume_base``), and carry a per-run ``budget`` — when it produces
``budget`` new tokens without finishing, the engine suspends it
(``Scheduler.suspend``) and hands it back resumable, to be resubmitted next
iteration under the then-current weights.

Admission PREFIX-MATCHES before it allocates: the longest chain of
block-aligned full blocks of the request's prompt head (prompt + seed) that
is still resident in the cache's prefix index is SHARED (``cache.share``,
one refcount each) instead of re-prefilled — the request only prefills its
divergent tail, always at least one token so there are last-token logits to
sample from.  The engine calls ``register_prefix`` as blocks fill (at
admission-prefill and at decode block boundaries), so

  * the 2nd..Nth member of a GRPO group prefills the shared prompt once,
  * a recompute-preemption refill re-matches the victim's own blocks if
    they were not reclaimed in the meantime, and
  * a budget-suspended request resumes nearly for free next run — its
    freed blocks stay indexed until actually evicted.

Shared blocks are copy-on-extend by construction: only FULL, immutable
prefix blocks are ever indexed/shared, and a sequence's writes (tail
prefill, decode) land strictly past its matched prefix in freshly
allocated blocks, so no write ever touches a block another slot reads.

The scheduler is pure host-side bookkeeping (numpy block tables, python
queues); the engine owns all device work.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.obs import get_tracer
from repro.serve.paged_cache import PagedKVCache, blocks_for, prefix_key


class OutOfBlocksError(RuntimeError):
    """KV pool exhausted and no preemption victim available."""


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32 — original prompt
    max_new: int                       # max NEW tokens this submission emits
    budget: int | None = None          # suspend (resumable) after this many
    #                                    new tokens; None => run to max_new
    submitted_at: float = field(default_factory=time.perf_counter)
    # -- runtime state (scheduler/engine owned) -----------------------------
    # ``generated`` may be SEEDED at submission with tokens from earlier
    # iterations (mid-sequence submit); ``resume_base`` marks how many, so
    # ``max_new``/``budget`` count only tokens generated since this submit.
    generated: list = field(default_factory=list)    # sampled token ids
    gen_logp: list = field(default_factory=list)
    resume_base: int = 0
    slot: int = -1
    cache_len: int = 0                 # VALID KV rows in the paged cache —
    #                                    seeded with the prefix-matched rows
    #                                    at admission, grown by the engine's
    #                                    (chunked) tail prefill, then by one
    #                                    per decode step
    prefill_len: int = 0               # admission target: len(prompt + seed);
    #                                    cache_len < prefill_len => the slot
    #                                    is still PREFILLING (no decode)
    shared_rows: int = 0               # rows satisfied by prefix match at the
    #                                    latest admission (stats/tests)
    registered: int = 0                # full blocks already in the prefix
    #                                    index (-1: never register — stale
    #                                    weights era, see flush_prefix)
    key_chain: list = field(default_factory=list)  # chained prefix keys per
    #                                    full block of prompt+generated;
    #                                    append-only (the stream's prefix
    #                                    never changes), so it survives
    #                                    preemption and re-admission
    preemptions: int = 0
    first_token_at: float = -1.0
    finished_at: float = -1.0
    # prefill stash: (k, v) rows (n, P, kv, hd) + presampled first token —
    # set by the batch generate() path, which prefills all prompts in ONE
    # jitted call (bit-identical to RolloutEngine's prefill) and injects the
    # rows at admission time instead of re-running prefill per slot.
    stash: tuple | None = None

    @property
    def refill_tokens(self) -> np.ndarray:
        """Tokens to prefill on (re-)admission: prompt + generated so far."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def num_new(self) -> int:
        """Tokens generated since this submission (excludes the seed)."""
        return len(self.generated) - self.resume_base

    @property
    def total_len(self) -> int:
        return len(self.prompt) + self.resume_base + self.max_new


class Scheduler:
    """Slot + block bookkeeping for the serving engine."""

    def __init__(self, cache: PagedKVCache, max_slots: int,
                 prefix_cache: bool = True, tracer=None):
        self.cache = cache
        self.max_slots = max_slots
        # lifecycle instants (serve.admit / serve.preempt / serve.suspend /
        # serve.finish) land on the same timeline as the engine's step spans;
        # a disabled tracer makes every emission a no-op
        self.tracer = tracer if tracer is not None else get_tracer()
        self.block_size = cache.block_size
        self.max_blocks = cache.max_blocks_per_seq
        self.prefix_cache = prefix_cache
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}
        self.tables = np.full((max_slots, self.max_blocks), cache.null_block,
                              np.int32)
        # min-heap: admission always picks the smallest free slot (same
        # deterministic order the old sorted-list pop(0) gave, but O(log S))
        self._free_slots = list(range(max_slots))
        self._blocks: dict[int, list[int]] = {s: [] for s in range(max_slots)}
        self._admit_order: list[int] = []   # running slots, oldest first
        self.shared_rows_total = 0          # prefix-matched rows, lifetime

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        need = blocks_for(req.total_len, self.block_size)
        if need > self.max_blocks:
            seed = (f" + seed {req.resume_base}" if req.resume_base else "")
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)}{seed} + "
                f"max_new {req.max_new} needs {need} blocks > "
                f"max_blocks_per_seq {self.max_blocks}")
        if need > self.cache.num_blocks:
            raise ValueError(
                f"request {req.rid} needs {need} blocks but the pool only "
                f"has {self.cache.num_blocks}; it could never be scheduled")
        self.waiting.append(req)

    @property
    def num_pending(self) -> int:
        return len(self.waiting)

    @property
    def num_running(self) -> int:
        return len(self.running)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running

    # -- admission ----------------------------------------------------------
    def _block_key(self, req: Request, i: int, toks: np.ndarray) -> bytes:
        """Chained prefix key of full block ``i`` of ``toks``, memoized on
        the request (the stream's prefix is append-only, so the chain stays
        valid across preemptions, suspends and growth)."""
        bs = self.block_size
        chain = req.key_chain
        while len(chain) <= i:
            j = len(chain)
            chain.append(prefix_key(chain[j - 1] if j else b"",
                                    toks[j * bs:(j + 1) * bs]))
        return chain[i]

    def _match(self, req: Request, toks: np.ndarray) -> list[int]:
        """Longest chain of indexed full blocks covering a block-aligned
        head of ``toks``, capped so at least ONE token is left to prefill
        (the tail prefill's last-token logits seed sampling)."""
        if not self.prefix_cache:
            return []
        chain: list[int] = []
        for i in range((len(toks) - 1) // self.block_size):
            b = self.cache.lookup(self._block_key(req, i, toks))
            if b is None:
                break
            chain.append(b)
        return chain

    def admit(self, limit: int | None = None) -> list[Request]:
        """Move queued requests into free slots while both a slot and enough
        blocks for their prefill (+1 decode write) exist.  FIFO — the head
        blocks the queue (no head-of-line skipping, keeps latency fair).

        Each admission first prefix-matches the request's prompt head
        (prompt + seed) against the cache index: matched blocks are SHARED
        (refcount +1 each, reviving freed-but-cached ones) and only the
        remainder is freshly allocated, with ``cache_len`` seeded to the
        matched rows so the engine prefills the tail alone.  The engine
        admits one request at a time (``limit=1``) and registers its blocks
        before the next admission, so even two group members admitted in the
        same step share the head."""
        admitted = []
        while self.waiting and self._free_slots and (
                limit is None or len(admitted) < limit):
            req = self.waiting[0]
            toks = req.refill_tokens
            need = blocks_for(len(toks) + 1, self.block_size)
            shared = self._match(req, toks)
            revive = sum(1 for b in shared if self.cache.refcount(b) == 0)
            if self.cache.num_free - revive < need - len(shared):
                break
            self.waiting.popleft()
            slot = heapq.heappop(self._free_slots)
            for b in shared:
                self.cache.share(b)
            blocks = shared + [self.cache.alloc()
                               for _ in range(need - len(shared))]
            self._blocks[slot] = blocks
            self.tables[slot, :] = self.cache.null_block
            self.tables[slot, :need] = blocks
            req.slot = slot
            req.cache_len = len(shared) * self.block_size
            req.prefill_len = len(toks)
            req.shared_rows = req.cache_len
            req.registered = len(shared)    # matched blocks already indexed
            self.shared_rows_total += req.cache_len
            self.running[slot] = req
            self._admit_order.append(slot)
            admitted.append(req)
            if self.tracer.enabled:
                self.tracer.instant("serve.admit", cat="serve", args={
                    "rid": req.rid, "slot": slot,
                    "prefill_len": req.prefill_len,
                    "shared_rows": req.shared_rows})
        return admitted

    def rematch(self, req: Request) -> int:
        """Upgrade a request's prefix match just before its FIRST tail chunk
        runs (chunked prefill admits a whole wave before any prefill
        executes, so a group member admitted alongside the group head finds
        the head's blocks only now).  Extra matched blocks replace the
        request's own fresh allocations for the same rows — those are
        unwritten and unindexed, so they simply return to the free
        structure.  Returns the newly shared row count."""
        if (not self.prefix_cache or req.slot < 0 or req.registered < 0
                or req.cache_len != req.shared_rows):
            return 0                       # tail already started: rows final
        bs = self.block_size
        have = req.cache_len // bs
        chain = self._match(req, req.refill_tokens)
        if len(chain) <= have:
            return 0
        blocks = self._blocks[req.slot]
        for i in range(have, len(chain)):
            self.cache.share(chain[i])
            self.cache.free([blocks[i]])
            blocks[i] = chain[i]
            self.tables[req.slot, i] = chain[i]
        gained = (len(chain) - have) * bs
        req.cache_len = len(chain) * bs
        req.shared_rows = req.cache_len
        req.registered = max(req.registered, len(chain))
        self.shared_rows_total += gained
        return gained

    def register_prefix(self, req: Request) -> None:
        """Index every newly-FULL block of ``req``'s stream (prompt + all
        generated so far) so later admissions — group members, preemption
        refills, partial-rollout resumes — can share it.  Called by the
        engine after each tail-prefill write and at decode block
        boundaries, always BEFORE the blocks could be freed."""
        if not self.prefix_cache or req.slot < 0 or req.registered < 0:
            return
        bs = self.block_size
        toks = req.refill_tokens           # rows [0, cache_len) cache these
        nfull = min(req.cache_len, len(toks)) // bs
        blocks = self._blocks[req.slot]
        for i in range(req.registered, nfull):
            self.cache.register(self._block_key(req, i, toks), blocks[i])
        req.registered = max(req.registered, nfull)

    def flush_prefix(self) -> None:
        """Invalidate the prefix index (the engine saw new weights): resident
        KV no longer matches what a fresh prefill would write.  Allocations
        are untouched — running requests keep decoding on their own rows,
        but they are never matched or re-registered again."""
        self.cache.flush_index()
        for req in self.running.values():
            req.registered = -1

    # -- growth / preemption ------------------------------------------------
    def ensure_capacity(self) -> list[Request]:
        """Guarantee every running slot owns a block for its next KV write.
        Preempts (recompute-style) youngest-first when the pool runs dry.
        Returns the preempted requests (already re-queued)."""
        preempted: list[Request] = []
        for slot in list(self._admit_order):
            req = self.running.get(slot)
            if req is None:
                continue
            need = blocks_for(req.cache_len + 1, self.block_size)
            while len(self._blocks[slot]) < need:
                if self.cache.num_free > 0:
                    blk = self.cache.alloc()
                    self.tables[slot, len(self._blocks[slot])] = blk
                    self._blocks[slot].append(blk)
                    continue
                victim_slot = self._admit_order[-1]
                victim = self._preempt(victim_slot)
                preempted.append(victim)
                if victim_slot == slot:
                    break              # preempted ourselves; slot is gone
        return preempted

    def _preempt(self, slot: int) -> Request:
        req = self.running[slot]
        if self.tracer.enabled:
            self.tracer.instant("serve.preempt", cat="serve", args={
                "rid": req.rid, "slot": slot, "cache_len": req.cache_len})
        self._release(slot)
        req.preemptions += 1
        req.slot = -1
        req.cache_len = 0
        req.prefill_len = 0
        req.shared_rows = 0
        req.registered = 0
        req.stash = None               # KV dropped -> recompute on readmission
        self.waiting.appendleft(req)   # resume FIRST (cf. partial rollout)
        return req

    # -- eviction -----------------------------------------------------------
    def finish(self, slot: int) -> Request:
        req = self.running[slot]
        if self.tracer.enabled:
            self.tracer.instant("serve.finish", cat="serve", args={
                "rid": req.rid, "slot": slot,
                "new_tokens": req.num_new,
                "preemptions": req.preemptions})
        self._release(slot)
        req.finished_at = time.perf_counter()
        return req

    def suspend(self, slot: int) -> Request:
        """Evict a request that exhausted its per-run ``budget`` without
        finishing: slot and KV blocks are freed NOW; the caller owns the
        request and may resubmit it mid-sequence later (re-prefill, like a
        recompute preemption — but across engine runs, not within one).
        The freed blocks KEEP their prefix-index entries until actually
        reclaimed, so a resume within the same weights era re-matches them
        and the re-prefill is nearly free."""
        req = self.running[slot]
        if self.tracer.enabled:
            self.tracer.instant("serve.suspend", cat="serve", args={
                "rid": req.rid, "slot": slot, "new_tokens": req.num_new})
        self._release(slot)
        req.slot = -1
        req.cache_len = 0
        req.prefill_len = 0
        req.shared_rows = 0
        req.registered = 0
        req.stash = None
        return req

    def _release(self, slot: int) -> None:
        self.cache.free(self._blocks[slot])
        self._blocks[slot] = []
        self.tables[slot, :] = self.cache.null_block
        del self.running[slot]
        self._admit_order.remove(slot)
        heapq.heappush(self._free_slots, slot)

    # -- debugging ----------------------------------------------------------
    def check_invariants(self) -> None:
        cache = self.cache
        owned: dict[int, int] = {}
        for s in range(self.max_slots):
            for b in self._blocks[s]:
                owned[b] = owned.get(b, 0) + 1
        for b in range(cache.num_blocks):
            assert cache.refcount(b) == owned.get(b, 0), \
                f"block {b}: refcount {cache.refcount(b)} != " \
                f"{owned.get(b, 0)} slot references"
        assert not (set(owned) & cache._free_set), "owned block in free set"
        assert len(owned) + cache.num_free == cache.num_blocks, "block leak"
        assert sorted(self.running) == sorted(self._admit_order)
        for slot, req in self.running.items():
            assert len(self._blocks[slot]) >= blocks_for(
                max(req.cache_len, 1), self.block_size)
            for j, b in enumerate(self._blocks[slot]):
                assert self.tables[slot, j] == b
        # prefix index: entries point only at RESIDENT blocks (owned or
        # freed-but-cached), and the two maps mirror each other
        for key, b in cache._index.items():
            assert cache._block_key.get(b) == key, (b, key)
            assert cache.refcount(b) > 0 or b in cache._free_set, \
                f"indexed block {b} neither referenced nor free-cached"
