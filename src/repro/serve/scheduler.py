"""Continuous-batching request scheduler.

Requests queue PRIORITY-then-FIFO (``AdmissionQueue``) and are admitted into
one of ``max_slots`` serving slots whenever a slot AND enough KV blocks for
their prompt (+1 decode token) are free.  A finished sequence (EOS or
per-request token budget) is evicted the moment it completes and its slot
refilled from the queue — no batch barrier, which is the whole point versus
the synchronized ``RolloutEngine``.

When a running sequence needs a new block and the pool is dry, the scheduler
preempts the LOWEST-priority running request, youngest first within that
class (vLLM's recompute preemption; with uniform priorities this is exactly
the classic youngest-first rule): its blocks are released, and the request
re-queues at the FRONT of its priority class with its generated-so-far
tokens folded into the prompt, to be re-prefilled on re-admission.
Priorities steer only WHICH request runs when resources are contended —
never what any request computes: per-request sampling streams
(``core/rollout.request_stream``) make every request's tokens independent
of admission order, so priority reshuffling is output-invariant.

The SAME re-prefill path serves cross-iteration partial rollout
(``core/partial.py``): a request may be submitted MID-SEQUENCE, seeded with
the tokens generated in earlier iterations (``generated`` +
``resume_base``), and carry a per-run ``budget`` — when it produces
``budget`` new tokens without finishing, the engine suspends it
(``Scheduler.suspend``) and hands it back resumable, to be resubmitted next
iteration under the then-current weights.

Admission PREFIX-MATCHES before it allocates: the longest chain of
block-aligned full blocks of the request's prompt head (prompt + seed) that
is still resident in the cache's prefix index is SHARED (``cache.share``,
one refcount each) instead of re-prefilled — the request only prefills its
divergent tail, always at least one token so there are last-token logits to
sample from.  The engine calls ``register_prefix`` as blocks fill (at
admission-prefill and at decode block boundaries), so

  * the 2nd..Nth member of a GRPO group prefills the shared prompt once,
  * a recompute-preemption refill re-matches the victim's own blocks if
    they were not reclaimed in the meantime, and
  * a budget-suspended request resumes nearly for free next run — its
    freed blocks stay indexed until actually evicted.

Shared blocks are copy-on-extend by construction: only FULL, immutable
prefix blocks are ever indexed/shared, and a sequence's writes (tail
prefill, decode) land strictly past its matched prefix in freshly
allocated blocks, so no write ever touches a block another slot reads.

The scheduler is pure host-side bookkeeping (numpy block tables, python
queues); the engine owns all device work.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field

import numpy as np

from repro.obs import MetricsRegistry, get_tracer
from repro.serve.paged_cache import PagedKVCache, blocks_for, prefix_key


class OutOfBlocksError(RuntimeError):
    """KV pool exhausted and no preemption victim available."""


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32 — original prompt
    max_new: int                       # max NEW tokens this submission emits
    budget: int | None = None          # suspend (resumable) after this many
    #                                    new tokens; None => run to max_new
    priority: int = 0                  # admission/victim class: higher runs
    #                                    first and is preempted last; FIFO
    #                                    within a class (AdmissionQueue)
    seed: int | None = None            # sampling-stream identity: the engine
    #                                    derives ``stream`` from its run key
    #                                    + this (defaults to rid); resubmit
    #                                    with the SAME seed to continue the
    #                                    stream across engine runs
    stream: np.ndarray | None = None   # (2,) uint32 per-request PRNG stream
    #                                    root — token t is sampled with
    #                                    fold_in(stream, t), so sampling is
    #                                    schedule-independent (None: greedy
    #                                    or direct scheduler-level use)
    submitted_at: float = field(default_factory=time.perf_counter)
    # -- runtime state (scheduler/engine owned) -----------------------------
    # ``generated`` may be SEEDED at submission with tokens from earlier
    # iterations (mid-sequence submit); ``resume_base`` marks how many, so
    # ``max_new``/``budget`` count only tokens generated since this submit.
    generated: list = field(default_factory=list)    # sampled token ids
    gen_logp: list = field(default_factory=list)
    resume_base: int = 0
    slot: int = -1
    cache_len: int = 0                 # VALID KV rows in the paged cache —
    #                                    seeded with the prefix-matched rows
    #                                    at admission, grown by the engine's
    #                                    (chunked) tail prefill, then by one
    #                                    per decode step
    prefill_len: int = 0               # admission target: len(prompt + seed);
    #                                    cache_len < prefill_len => the slot
    #                                    is still PREFILLING (no decode)
    shared_rows: int = 0               # rows satisfied by prefix match at the
    #                                    latest admission (stats/tests)
    registered: int = 0                # full blocks already in the prefix
    #                                    index (-1: never register — stale
    #                                    weights era, see flush_prefix)
    bridged: bool = False              # this admission's prefix match used a
    #                                    HOST-tier hit; any later match
    #                                    extension (rematch) must then stay
    #                                    host-only — see Scheduler._match
    key_chain: list = field(default_factory=list)  # chained prefix keys per
    #                                    full block of prompt+generated;
    #                                    append-only (the stream's prefix
    #                                    never changes), so it survives
    #                                    preemption and re-admission
    preemptions: int = 0
    wait_skips: int = 0                # admissions that jumped past this
    #                                    request while it waited (starvation
    #                                    accounting — see AdmissionQueue)
    first_token_at: float = -1.0
    finished_at: float = -1.0
    # prefill stash: (k, v) rows (n, P, kv, hd) + presampled first token —
    # set by the batch generate() path, which prefills all prompts in ONE
    # jitted call (bit-identical to RolloutEngine's prefill) and injects the
    # rows at admission time instead of re-running prefill per slot.
    stash: tuple | None = None

    @property
    def refill_tokens(self) -> np.ndarray:
        """Tokens to prefill on (re-)admission: prompt + generated so far."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def num_new(self) -> int:
        """Tokens generated since this submission (excludes the seed)."""
        return len(self.generated) - self.resume_base

    @property
    def total_len(self) -> int:
        return len(self.prompt) + self.resume_base + self.max_new


class AdmissionQueue:
    """Priority-then-FIFO admission queue with a starvation bound.

    A max-heap over ``(-priority, seq)``: higher ``Request.priority`` is
    admitted first; within a class, FIFO by a monotonic sequence number.
    ``appendleft`` (preemption/rollback re-queue) assigns a seq BELOW every
    live entry, so a preempted request resumes at the front of its class —
    with uniform priorities the queue degenerates to exactly the plain
    deque the scheduler used before priorities existed.

    Starvation bound: each ``popleft`` (= one admission) bumps
    ``wait_skips`` on every entry that was submitted EARLIER than the
    admitted one.  Once the globally-oldest entry has been jumped
    ``starvation_limit`` times, it becomes the head regardless of priority
    (``serve.priority.bypass`` counts these), so bulk traffic is delayed by
    interactive traffic but never parked forever.

    Heap entries are ``[-priority, seq, req]`` with seq unique, so tuple
    comparison never reaches the Request (whose dataclass ``__eq__`` would
    choke on ndarray fields).  The selection rule lives in ``_candidate``
    — ``[0]`` (peek) and ``popleft`` agree by construction, which
    ``Scheduler.admit``'s peek-check-pop sequence relies on."""

    def __init__(self, starvation_limit: int = 8, metrics=None):
        if starvation_limit < 1:
            raise ValueError(
                f"starvation_limit must be >= 1, got {starvation_limit}")
        self.starvation_limit = starvation_limit
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._heap: list[list] = []    # [-priority, seq, req]
        self._back = 0                 # next append seq (grows)
        self._front = -1               # next appendleft seq (shrinks)

    # -- deque-compatible surface (engine + tests use these) ---------------
    def append(self, req: Request) -> None:
        heapq.heappush(self._heap, [-req.priority, self._back, req])
        self._back += 1

    def appendleft(self, req: Request) -> None:
        """Front-of-class re-queue (preemption, admission rollback): the
        request outranks every same-priority entry, exactly like the old
        deque's appendleft under uniform priorities."""
        heapq.heappush(self._heap, [-req.priority, self._front, req])
        self._front -= 1

    def extend(self, reqs) -> None:
        for req in reqs:
            self.append(req)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __iter__(self):
        """Admission order (priority desc, FIFO within class; the
        starvation bypass is a pop-time head adjustment, not reflected
        here)."""
        return (e[2] for e in sorted(self._heap, key=lambda e: e[:2]))

    def _candidate(self) -> list:
        """The heap entry the next ``popleft`` admits: the heap top, unless
        the globally-oldest waiting request has been jumped
        ``starvation_limit``+ times — then the oldest."""
        top = self._heap[0]
        oldest = min(self._heap, key=lambda e: e[1])
        if oldest is not top and oldest[2].wait_skips >= self.starvation_limit:
            return oldest
        return top

    def __getitem__(self, i: int):
        if i != 0:
            raise IndexError("AdmissionQueue only exposes the head ([0])")
        if not self._heap:
            raise IndexError("peek from an empty AdmissionQueue")
        return self._candidate()[2]

    def popleft(self) -> Request:
        if not self._heap:
            raise IndexError("popleft from an empty AdmissionQueue")
        entry = self._candidate()
        if entry is self._heap[0]:
            heapq.heappop(self._heap)
        else:                          # starvation bypass: out-of-heap-order
            self.metrics.inc("serve.priority.bypass")
            self._heap = [e for e in self._heap if e is not entry]
            heapq.heapify(self._heap)
        for e in self._heap:
            if e[1] < entry[1]:        # submitted earlier, jumped again
                e[2].wait_skips += 1
        return entry[2]

    # -- debugging ----------------------------------------------------------
    def check_invariants(self) -> None:
        seqs = [e[1] for e in self._heap]
        assert len(seqs) == len(set(seqs)), "duplicate queue seq"
        assert all(self._front < s < self._back for s in seqs), \
            "queue seq outside the live [front, back] window"
        for e in self._heap:
            assert e[0] == -e[2].priority, \
                f"heap rank {e[0]} stale vs request priority {e[2].priority}"
            assert e[2].slot == -1, \
                f"waiting request {e[2].rid} still claims slot {e[2].slot}"
            assert e[2].wait_skips >= 0
        if self._heap:
            cand = self._candidate()[2]
            best = max(e[2].priority for e in self._heap)
            assert (cand.priority == best
                    or cand.wait_skips >= self.starvation_limit), \
                "queue head neither top-priority nor a starvation bypass"


class Scheduler:
    """Slot + block bookkeeping for the serving engine."""

    def __init__(self, cache: PagedKVCache, max_slots: int,
                 prefix_cache: bool = True, tracer=None, metrics=None,
                 starvation_limit: int = 8):
        self.cache = cache
        self.max_slots = max_slots
        # lifecycle instants (serve.admit / serve.preempt / serve.suspend /
        # serve.finish) land on the same timeline as the engine's step spans;
        # a disabled tracer makes every emission a no-op.  The registry
        # (engine-shared) ticks the swap-vs-recompute preemption split.
        self.tracer = tracer if tracer is not None else get_tracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.block_size = cache.block_size
        self.max_blocks = cache.max_blocks_per_seq
        self.prefix_cache = prefix_cache
        self.waiting = AdmissionQueue(starvation_limit=starvation_limit,
                                      metrics=self.metrics)
        self.running: dict[int, Request] = {}
        self.tables = np.full((max_slots, self.max_blocks), cache.null_block,
                              np.int32)
        # min-heap: admission always picks the smallest free slot (same
        # deterministic order the old sorted-list pop(0) gave, but O(log S))
        self._free_slots = list(range(max_slots))
        self._blocks: dict[int, list[int]] = {s: [] for s in range(max_slots)}
        self._admit_order: list[int] = []   # running slots, oldest first
        self.shared_rows_total = 0          # prefix-matched rows, lifetime

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        need = blocks_for(req.total_len, self.block_size)
        if need > self.max_blocks:
            seed = (f" + seed {req.resume_base}" if req.resume_base else "")
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)}{seed} + "
                f"max_new {req.max_new} needs {need} blocks > "
                f"max_blocks_per_seq {self.max_blocks}")
        if need > self.cache.num_blocks:
            raise ValueError(
                f"request {req.rid} needs {need} blocks but the pool only "
                f"has {self.cache.num_blocks}; it could never be scheduled")
        self.waiting.append(req)

    @property
    def num_pending(self) -> int:
        return len(self.waiting)

    @property
    def num_running(self) -> int:
        return len(self.running)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running

    # -- admission ----------------------------------------------------------
    def _block_key(self, req: Request, i: int, toks: np.ndarray) -> bytes:
        """Chained prefix key of full block ``i`` of ``toks``, memoized on
        the request (the stream's prefix is append-only, so the chain stays
        valid across preemptions, suspends and growth)."""
        bs = self.block_size
        chain = req.key_chain
        while len(chain) <= i:
            j = len(chain)
            chain.append(prefix_key(chain[j - 1] if j else b"",
                                    toks[j * bs:(j + 1) * bs]))
        return chain[i]

    def _match(self, req: Request, toks: np.ndarray, start: int = 0,
               bridged: bool = False) -> list[tuple]:
        """Longest chain of RESIDENT full blocks covering blocks ``start..``
        of ``toks``'s block-aligned head, capped so at least ONE token is
        left to prefill (the tail prefill's last-token logits seed
        sampling).  Each entry is ``("dev", block)`` for a device-index hit
        or ``("host", key)`` for one resident only in the host tier (to be
        claimed with ``cache.swap_in``); without a host tier the chain is
        all-dev and this is the classic single-level match.

        Once the chain crosses a HOST hit it may extend only through host
        hits (``bridged``) — never back into device blocks.  A host bridge
        reaches content the tier-less scheduler could not (its chain breaks
        at the reclaimed block), and reviving device blocks beyond the
        bridge would (a) share blocks the tier-less run fresh-allocates,
        skewing pool pressure and hence scheduling, and (b) revive DECODE-
        written rows where the tier-less run re-prefills — and decode KV is
        not bit-reproducible by prefill.  Host-run-only continuation maps
        1:1 onto the tier-less run's recompute (one swap-in target per
        fresh block, prefill-provenance bytes only), which is what makes
        greedy gen AND gen_logp bitwise invariant tier on/off."""
        if not self.prefix_cache:
            return []
        chain: list[tuple] = []
        for i in range(start, (len(toks) - 1) // self.block_size):
            key = self._block_key(req, i, toks)
            b = self.cache.lookup(key)
            if b is not None and not bridged:
                chain.append(("dev", b))
            elif self.cache.lookup_host(key) is not None:
                chain.append(("host", key))
                bridged = True
            else:
                break
        return chain

    def admit(self, limit: int | None = None) -> list[Request]:
        """Move queued requests into free slots while both a slot and enough
        blocks for their prefill (+1 decode write) exist.  Priority-then-
        FIFO (``AdmissionQueue``) — the head blocks the queue (no
        head-of-line skipping past an infeasible head, keeps the admission
        order deterministic and latency fair within a class).

        Each admission first prefix-matches the request's prompt head
        (prompt + seed) against the cache index: matched blocks are SHARED
        (refcount +1 each, reviving freed-but-cached ones) and only the
        remainder is freshly allocated, with ``cache_len`` seeded to the
        matched rows so the engine prefills the tail alone.  The engine
        admits one request at a time (``limit=1``) and registers its blocks
        before the next admission, so even two group members admitted in the
        same step share the head."""
        admitted = []
        while self.waiting and self._free_slots and (
                limit is None or len(admitted) < limit):
            req = self.waiting[0]
            toks = req.refill_tokens
            need = blocks_for(len(toks) + 1, self.block_size)
            shared = self._match(req, toks)
            dev = [b for t, b in shared if t == "dev"]
            revive = sum(1 for b in dev if self.cache.refcount(b) == 0)
            # host hits still consume a device block each (the swap-in
            # target), so only DEV hits reduce the allocation demand
            if self.cache.num_free - revive < need - len(dev):
                break
            self.waiting.popleft()
            req.bridged = False
            slot = heapq.heappop(self._free_slots)
            # share every dev hit BEFORE any allocation: a refcount-0 hit
            # deep in the chain must not be reclaimed (and spilled out from
            # under us) by the swap-in targets allocated for earlier blocks
            for t, x in shared:
                if t == "dev":
                    self.cache.share(x)
            blocks: list[int] = []
            truncated = False
            for t, x in shared:
                if truncated:
                    if t == "dev":
                        self.cache.free([x])   # undo the guard share
                elif t == "dev":
                    blocks.append(x)
                else:
                    b = self.cache.swap_in(x)
                    if b is None:
                        # host-evicted between match and claim: the chain
                        # breaks here; deeper blocks re-prefill instead
                        # (a swap-in target alloc becomes a fresh alloc —
                        # the feasibility arithmetic above still holds)
                        truncated = True
                    else:
                        blocks.append(b)
                        req.bridged = True
            nshared = len(blocks)
            if truncated and self.cache.num_free < need - nshared:
                # truncation invalidated the feasibility check (deeper dev
                # hits were freed, not kept — a chain must be contiguous):
                # roll the whole admission back and retry next step.  The
                # already-swapped-in blocks stay indexed on DEVICE, so the
                # retry matches them as dev hits.
                self.cache.free(blocks)
                heapq.heappush(self._free_slots, slot)
                self.waiting.appendleft(req)
                break
            blocks += [self.cache.alloc() for _ in range(need - nshared)]
            self._blocks[slot] = blocks
            self.tables[slot, :] = self.cache.null_block
            self.tables[slot, :need] = blocks
            req.slot = slot
            req.cache_len = nshared * self.block_size
            req.prefill_len = len(toks)
            req.shared_rows = req.cache_len
            req.registered = nshared        # matched blocks already indexed
            self.shared_rows_total += req.cache_len
            self.running[slot] = req
            self._admit_order.append(slot)
            admitted.append(req)
            if self.tracer.enabled:
                self.tracer.instant("serve.admit", cat="serve", args={
                    "rid": req.rid, "slot": slot,
                    "prefill_len": req.prefill_len,
                    "shared_rows": req.shared_rows,
                    "priority": req.priority})
        return admitted

    def rematch(self, req: Request) -> int:
        """Upgrade a request's prefix match just before its FIRST tail chunk
        runs (chunked prefill admits a whole wave before any prefill
        executes, so a group member admitted alongside the group head finds
        the head's blocks only now).  Extra matched blocks replace the
        request's own fresh allocations for the same rows — those are
        unwritten and unindexed, so they simply return to the free
        structure.  Returns the newly shared row count."""
        if (not self.prefix_cache or req.slot < 0 or req.registered < 0
                or req.cache_len != req.shared_rows):
            return 0                       # tail already started: rows final
        bs = self.block_size
        have = req.cache_len // bs
        # resume the match walk past the already-shared prefix, carrying the
        # admission's bridge state: once this request claimed a host block it
        # may only extend through further host hits (``_match``'s rule)
        ext = self._match(req, req.refill_tokens, start=have,
                          bridged=req.bridged)
        if not ext:
            return 0
        blocks = self._blocks[req.slot]
        upto = have
        for off, (t, x) in enumerate(ext):
            i = have + off
            if t == "dev":
                self.cache.share(x)
                self.cache.free([blocks[i]])
                blocks[i] = x
                self.tables[req.slot, i] = x
            elif self.cache.swap_in(x, into=blocks[i]) is None:
                break                      # host-evicted: chain ends here
            else:
                # (host hit streams into the request's OWN fresh block —
                # unwritten and unindexed, so no replacement needed)
                req.bridged = True
            upto = i + 1
        gained = (upto - have) * bs
        req.cache_len = upto * bs
        req.shared_rows = req.cache_len
        req.registered = max(req.registered, upto)
        self.shared_rows_total += gained
        return gained

    def register_prefix(self, req: Request) -> None:
        """Index every newly-FULL block of ``req``'s stream (prompt + all
        generated so far) so later admissions — group members, preemption
        refills, partial-rollout resumes — can share it.  Called by the
        engine after each tail-prefill write and at decode block
        boundaries, always BEFORE the blocks could be freed."""
        if not self.prefix_cache or req.slot < 0 or req.registered < 0:
            return
        bs = self.block_size
        toks = req.refill_tokens           # rows [0, cache_len) cache these
        nfull = min(req.cache_len, len(toks)) // bs
        blocks = self._blocks[req.slot]
        for i in range(req.registered, nfull):
            self.cache.register(self._block_key(req, i, toks), blocks[i])
        req.registered = max(req.registered, nfull)

    def flush_prefix(self) -> None:
        """Invalidate the prefix index (the engine saw new weights): resident
        KV no longer matches what a fresh prefill would write.  Allocations
        are untouched — running requests keep decoding on their own rows,
        but they are never matched or re-registered again."""
        self.cache.flush_index()
        for req in self.running.values():
            req.registered = -1

    # -- growth / preemption ------------------------------------------------
    def _victim_slot(self) -> int:
        """Preemption victim: LOWEST priority running request; youngest
        (latest-admitted) within that class.  A strictly-higher-priority
        request is never evicted while a lower-priority one runs; with
        uniform priorities this reduces exactly to the classic
        youngest-first rule (``_admit_order[-1]``), so the priority-free
        bit-identity fixtures see unchanged scheduling."""
        pos = {s: i for i, s in enumerate(self._admit_order)}
        return min(self._admit_order,
                   key=lambda s: (self.running[s].priority, -pos[s]))

    def ensure_capacity(self) -> list[Request]:
        """Guarantee every running slot owns a block for its next KV write.
        Preempts (recompute-style) lowest-priority-youngest-first
        (``_victim_slot``) when the pool runs dry.  Returns the preempted
        requests (already re-queued)."""
        preempted: list[Request] = []
        for slot in list(self._admit_order):
            req = self.running.get(slot)
            if req is None:
                continue
            need = blocks_for(req.cache_len + 1, self.block_size)
            while len(self._blocks[slot]) < need:
                if self.cache.num_free > 0:
                    blk = self.cache.alloc()
                    self.tables[slot, len(self._blocks[slot])] = blk
                    self._blocks[slot].append(blk)
                    continue
                victim_slot = self._victim_slot()
                victim = self._preempt(victim_slot)
                preempted.append(victim)
                if victim_slot == slot:
                    break              # preempted ourselves; slot is gone
        return preempted

    def _preempt(self, slot: int) -> Request:
        req = self.running[slot]
        # swap-preemption vs recompute-preemption is a property of the
        # MEMORY system, not of this method: with a host tier the victim's
        # freed blocks spill (still-indexed) to host when reclaimed, and
        # re-admission swaps them back instead of re-prefilling.  Classify
        # by whether the victim has indexed blocks a swap could preserve
        # (registered > 0 — checked BEFORE the release resets it).
        swap = (self.cache.host is not None and self.prefix_cache
                and req.registered > 0)
        self.metrics.inc(
            "serve.preempt.swap" if swap else "serve.preempt.recompute")
        if self.tracer.enabled:
            self.tracer.instant("serve.preempt", cat="serve", args={
                "rid": req.rid, "slot": slot, "cache_len": req.cache_len,
                "swap": swap})
        self._release(slot)
        req.preemptions += 1
        req.slot = -1
        req.cache_len = 0
        req.prefill_len = 0
        req.shared_rows = 0
        req.registered = 0
        req.stash = None               # prefill stash dropped; indexed KV
        #                                survives in the tiered prefix index
        #                                (device until reclaimed, then host)
        self.waiting.appendleft(req)   # resume FIRST (cf. partial rollout)
        return req

    # -- eviction -----------------------------------------------------------
    def finish(self, slot: int) -> Request:
        req = self.running[slot]
        if self.tracer.enabled:
            self.tracer.instant("serve.finish", cat="serve", args={
                "rid": req.rid, "slot": slot,
                "new_tokens": req.num_new,
                "preemptions": req.preemptions})
        self._release(slot)
        req.finished_at = time.perf_counter()
        return req

    def suspend(self, slot: int) -> Request:
        """Evict a request that exhausted its per-run ``budget`` without
        finishing: slot and KV blocks are freed NOW; the caller owns the
        request and may resubmit it mid-sequence later (re-prefill, like a
        recompute preemption — but across engine runs, not within one).
        The freed blocks KEEP their prefix-index entries until actually
        reclaimed, so a resume within the same weights era re-matches them
        and the re-prefill is nearly free."""
        req = self.running[slot]
        if self.tracer.enabled:
            self.tracer.instant("serve.suspend", cat="serve", args={
                "rid": req.rid, "slot": slot, "new_tokens": req.num_new})
        self._release(slot)
        req.slot = -1
        req.cache_len = 0
        req.prefill_len = 0
        req.shared_rows = 0
        req.registered = 0
        req.stash = None
        return req

    def _release(self, slot: int) -> None:
        self.cache.free(self._blocks[slot])
        self._blocks[slot] = []
        self.tables[slot, :] = self.cache.null_block
        del self.running[slot]
        self._admit_order.remove(slot)
        heapq.heappush(self._free_slots, slot)

    # -- debugging ----------------------------------------------------------
    def check_invariants(self) -> None:
        cache = self.cache
        owned: dict[int, int] = {}
        for s in range(self.max_slots):
            for b in self._blocks[s]:
                owned[b] = owned.get(b, 0) + 1
        for b in range(cache.num_blocks):
            assert cache.refcount(b) == owned.get(b, 0), \
                f"block {b}: refcount {cache.refcount(b)} != " \
                f"{owned.get(b, 0)} slot references"
        assert not (set(owned) & cache._free_set), "owned block in free set"
        assert len(owned) + cache.num_free == cache.num_blocks, "block leak"
        assert sorted(self.running) == sorted(self._admit_order)
        # admission queue: heap/seq consistency, head-selection rule, and
        # strict waiting/running exclusivity
        self.waiting.check_invariants()
        waiting_ids = {id(r) for r in self.waiting}
        assert not waiting_ids & {id(r) for r in self.running.values()}, \
            "request simultaneously waiting and running"
        for slot, req in self.running.items():
            assert len(self._blocks[slot]) >= blocks_for(
                max(req.cache_len, 1), self.block_size)
            for j, b in enumerate(self._blocks[slot]):
                assert self.tables[slot, j] == b
        # prefix index: entries point only at RESIDENT blocks (owned or
        # freed-but-cached), and the two maps mirror each other
        for key, b in cache._index.items():
            assert cache._block_key.get(b) == key, (b, key)
            assert cache.refcount(b) > 0 or b in cache._free_set, \
                f"indexed block {b} neither referenced nor free-cached"
        if cache.host is not None:
            # tiered index exclusivity: a prefix key resolves in exactly
            # one tier, so no device block is ever simultaneously
            # free-deque-live, device-indexed AND host-resident (the
            # double-home state spill/swap-in must never create)
            both = set(cache._index) & set(cache.host._index)
            assert not both, f"{len(both)} key(s) resident in both tiers"
            cache.host.check_consistent()
