"""Continuous-batching request scheduler.

Requests queue FIFO and are admitted into one of ``max_slots`` serving slots
whenever a slot AND enough KV blocks for their prompt (+1 decode token) are
free.  A finished sequence (EOS or per-request token budget) is evicted the
moment it completes and its slot refilled from the queue — no batch barrier,
which is the whole point versus the synchronized ``RolloutEngine``.

When a running sequence needs a new block and the pool is dry, the scheduler
preempts the YOUNGEST running request (vLLM's recompute preemption): its
blocks are released, and the request re-queues at the FRONT with its
generated-so-far tokens folded into the prompt, to be re-prefilled on
re-admission.

The SAME re-prefill path serves cross-iteration partial rollout
(``core/partial.py``): a request may be submitted MID-SEQUENCE, seeded with
the tokens generated in earlier iterations (``generated`` +
``resume_base``), and carry a per-run ``budget`` — when it produces
``budget`` new tokens without finishing, the engine suspends it
(``Scheduler.suspend``) and hands it back resumable, to be resubmitted next
iteration under the then-current weights.

The scheduler is pure host-side bookkeeping (numpy block tables, python
queues); the engine owns all device work.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serve.paged_cache import PagedKVCache, blocks_for


class OutOfBlocksError(RuntimeError):
    """KV pool exhausted and no preemption victim available."""


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32 — original prompt
    max_new: int                       # max NEW tokens this submission emits
    budget: int | None = None          # suspend (resumable) after this many
    #                                    new tokens; None => run to max_new
    submitted_at: float = field(default_factory=time.perf_counter)
    # -- runtime state (scheduler/engine owned) -----------------------------
    # ``generated`` may be SEEDED at submission with tokens from earlier
    # iterations (mid-sequence submit); ``resume_base`` marks how many, so
    # ``max_new``/``budget`` count only tokens generated since this submit.
    generated: list = field(default_factory=list)    # sampled token ids
    gen_logp: list = field(default_factory=list)
    resume_base: int = 0
    slot: int = -1
    cache_len: int = 0                 # KV rows currently in the paged cache
    preemptions: int = 0
    first_token_at: float = -1.0
    finished_at: float = -1.0
    # prefill stash: (k, v) rows (n, P, kv, hd) + presampled first token —
    # set by the batch generate() path, which prefills all prompts in ONE
    # jitted call (bit-identical to RolloutEngine's prefill) and injects the
    # rows at admission time instead of re-running prefill per slot.
    stash: tuple | None = None

    @property
    def refill_tokens(self) -> np.ndarray:
        """Tokens to prefill on (re-)admission: prompt + generated so far."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    @property
    def num_new(self) -> int:
        """Tokens generated since this submission (excludes the seed)."""
        return len(self.generated) - self.resume_base

    @property
    def total_len(self) -> int:
        return len(self.prompt) + self.resume_base + self.max_new


class Scheduler:
    """Slot + block bookkeeping for the serving engine."""

    def __init__(self, cache: PagedKVCache, max_slots: int):
        self.cache = cache
        self.max_slots = max_slots
        self.block_size = cache.block_size
        self.max_blocks = cache.max_blocks_per_seq
        self.waiting: deque[Request] = deque()
        self.running: dict[int, Request] = {}
        self.tables = np.full((max_slots, self.max_blocks), cache.null_block,
                              np.int32)
        # min-heap: admission always picks the smallest free slot (same
        # deterministic order the old sorted-list pop(0) gave, but O(log S))
        self._free_slots = list(range(max_slots))
        self._blocks: dict[int, list[int]] = {s: [] for s in range(max_slots)}
        self._admit_order: list[int] = []   # running slots, oldest first

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        need = blocks_for(req.total_len, self.block_size)
        if need > self.max_blocks:
            seed = (f" + seed {req.resume_base}" if req.resume_base else "")
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)}{seed} + "
                f"max_new {req.max_new} needs {need} blocks > "
                f"max_blocks_per_seq {self.max_blocks}")
        if need > self.cache.num_blocks:
            raise ValueError(
                f"request {req.rid} needs {need} blocks but the pool only "
                f"has {self.cache.num_blocks}; it could never be scheduled")
        self.waiting.append(req)

    @property
    def num_pending(self) -> int:
        return len(self.waiting)

    @property
    def num_running(self) -> int:
        return len(self.running)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running

    # -- admission ----------------------------------------------------------
    def admit(self) -> list[Request]:
        """Move queued requests into free slots while both a slot and enough
        blocks for their prefill (+1 decode write) exist.  FIFO — the head
        blocks the queue (no head-of-line skipping, keeps latency fair)."""
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            need = blocks_for(len(req.refill_tokens) + 1, self.block_size)
            if self.cache.num_free < need:
                break
            self.waiting.popleft()
            slot = heapq.heappop(self._free_slots)
            blocks = [self.cache.alloc() for _ in range(need)]
            self._blocks[slot] = blocks
            self.tables[slot, :] = self.cache.null_block
            self.tables[slot, :need] = blocks
            req.slot = slot
            req.cache_len = 0          # engine sets it after the KV write
            self.running[slot] = req
            self._admit_order.append(slot)
            admitted.append(req)
        return admitted

    # -- growth / preemption ------------------------------------------------
    def ensure_capacity(self) -> list[Request]:
        """Guarantee every running slot owns a block for its next KV write.
        Preempts (recompute-style) youngest-first when the pool runs dry.
        Returns the preempted requests (already re-queued)."""
        preempted: list[Request] = []
        for slot in list(self._admit_order):
            req = self.running.get(slot)
            if req is None:
                continue
            need = blocks_for(req.cache_len + 1, self.block_size)
            while len(self._blocks[slot]) < need:
                if self.cache.num_free > 0:
                    blk = self.cache.alloc()
                    self.tables[slot, len(self._blocks[slot])] = blk
                    self._blocks[slot].append(blk)
                    continue
                victim_slot = self._admit_order[-1]
                victim = self._preempt(victim_slot)
                preempted.append(victim)
                if victim_slot == slot:
                    break              # preempted ourselves; slot is gone
        return preempted

    def _preempt(self, slot: int) -> Request:
        req = self.running[slot]
        self._release(slot)
        req.preemptions += 1
        req.slot = -1
        req.cache_len = 0
        req.stash = None               # KV dropped -> recompute on readmission
        self.waiting.appendleft(req)   # resume FIRST (cf. partial rollout)
        return req

    # -- eviction -----------------------------------------------------------
    def finish(self, slot: int) -> Request:
        req = self.running[slot]
        self._release(slot)
        req.finished_at = time.perf_counter()
        return req

    def suspend(self, slot: int) -> Request:
        """Evict a request that exhausted its per-run ``budget`` without
        finishing: slot and KV blocks are freed NOW; the caller owns the
        request and may resubmit it mid-sequence later (re-prefill, like a
        recompute preemption — but across engine runs, not within one)."""
        req = self.running[slot]
        self._release(slot)
        req.slot = -1
        req.cache_len = 0
        req.stash = None
        return req

    def _release(self, slot: int) -> None:
        self.cache.free(self._blocks[slot])
        self._blocks[slot] = []
        self.tables[slot, :] = self.cache.null_block
        del self.running[slot]
        self._admit_order.remove(slot)
        heapq.heappush(self._free_slots, slot)

    # -- debugging ----------------------------------------------------------
    def check_invariants(self) -> None:
        owned = [b for s in range(self.max_slots) for b in self._blocks[s]]
        assert len(owned) == len(set(owned)), "block double-assignment"
        assert not (set(owned) & set(self.cache._free)), "owned block in free list"
        assert len(owned) + self.cache.num_free == self.cache.num_blocks, \
            "block leak"
        assert sorted(self.running) == sorted(self._admit_order)
        for slot, req in self.running.items():
            assert len(self._blocks[slot]) >= blocks_for(
                max(req.cache_len, 1), self.block_size)
            for j, b in enumerate(self._blocks[slot]):
                assert self.tables[slot, j] == b
