"""Host-memory KV tier: swap, don't recompute.

The paged pool (serve/paged_cache.py) is the DEVICE tier of the KV cache;
this module adds the HOST tier beneath it — the serving-side analogue of
the paper's allgather-swap memory strategy (state that is not needed on
the accelerator right now should live in host RAM, not be recomputed).

Two pieces:

  * ``HostKVTier`` — a numpy-backed block store of ``num_blocks`` host
    slots, each holding one device block's rows ``(layers, block_size,
    kv, hd)``, addressed by the SAME chained prefix keys the device index
    uses (``prefix_key``).  Together the two indexes form one tiered
    prefix index: a key resolves in exactly ONE tier at a time (spilling
    moves the entry down, swap-in moves it back up), so effective prefix-
    cache capacity is bounded by host RAM, not the device pool.  Eviction
    within the host tier is LRU over an ``OrderedDict``.
  * ``SwapEngine`` — the async mover.  ONE background worker drains a
    BOUNDED job queue, issuing ``jax.device_get`` for spills (device
    block -> host slot) and ``jax.device_put`` for swap-ins (host slot ->
    staging buffer -> device rows).  The queue bound doubles as the
    staging depth: at most ``depth`` blocks are in flight, each swap-in
    owns one of ``depth`` preallocated host staging buffers
    (double-buffered by default), and a full queue back-pressures the
    submitter instead of growing.

Why swap beats recompute: a spilled block's bytes came out of the device
pool with ``device_get`` and go back with ``device_put`` — the round trip
is byte-exact, so a swapped-in block is BIT-IDENTICAL to the block that
left.  Recompute-preemption re-prefills the same tokens under the same
weights, which (by the prefix-cache contract) also reproduces the same
bits — but pays the prefill FLOPs again.  Swap pays a PCIe/host-memcpy
copy instead, and the greedy bit-identity contract holds with the tier on
or off because both paths materialize the same pool bytes.

Determinism with an async engine: all BOOKKEEPING (index moves, slot
claims, counters) happens synchronously on the caller's thread; only the
byte movement is asynchronous.  The cache drains pending swap-ins the
first time its pools are READ after a swap-in was scheduled
(``PagedKVCache._apply_swap_ins``), so compute never observes a
half-arrived block and the step order stays deterministic.  Spills need
no drain before reuse of the DEVICE block (the source slice is an
immutable jax array — a snapshot by construction); reuse of the HOST slot
is ordered by the single-worker FIFO queue (a later write to the same
slot is executed after the earlier one).  The one cross-thread wait is
``take()`` on a slot whose spill is still in flight — tracked per slot
and rare (a block swapped back in the same breath it was spilled).

The tier is intentionally ignorant of scheduling: it never decides WHAT
to spill or swap in.  ``PagedKVCache.alloc()`` spills on reclaim,
``Scheduler``'s admission matches host-resident keys and calls
``PagedKVCache.swap_in`` — see those modules.
"""
from __future__ import annotations

import queue
import threading
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.obs import NULL_SPAN, MetricsRegistry, get_tracer


class SwapWorkerError(RuntimeError):
    """The swap worker failed a job.  Raised on the CALLER's thread at the
    next submit/drain point; the recovery policy (docs/resilience.md) is
    permanent degradation — the tier is a cache over recomputable state,
    so ``PagedKVCache`` drops it wholesale and the engine falls back to
    recompute-preemption, preserving greedy bit-identity (tier-off is
    proven bitwise-equal to tier-on)."""


class SwapEngine:
    """Async host<->device block mover (one worker, bounded staging).

    Jobs are tuples: ``("out", host_slot, dev_k, dev_v)`` copies a device
    block's rows into the tier's store (``jax.device_get`` via
    ``np.asarray``); ``("in", flat_rows, stage)`` uploads staging buffer
    ``stage`` (``jax.device_put`` via ``jnp.array``) and parks the device
    arrays on the ready list for the cache's next drain point to scatter.
    ``depth`` bounds BOTH the job queue and the swap-in staging ring, so
    at most ``depth`` blocks are ever in flight — submission blocks when
    the engine is that far behind (back-pressure, not growth).
    """

    def __init__(self, tier: "HostKVTier", *, depth: int = 2, tracer=None,
                 faults=None):
        self.tier = tier
        self.depth = depth
        self.tracer = tracer if tracer is not None else get_tracer()
        self.faults = faults               # FaultPlan | None (chaos hook)
        self._jobs: queue.Queue = queue.Queue(maxsize=depth)
        self._cond = threading.Condition()
        self._pending = 0                  # guarded-by: _cond — not yet run
        self._ready: list[tuple] = []      # guarded-by: _cond — (flat, k, v)
        self._failed_in: list = []         # guarded-by: _cond — flat_rows of
        #                                    swap-ins whose upload never ran
        self._error: BaseException | None = None  # guarded-by: _cond
        self._thread: threading.Thread | None = None
        # swap-in staging ring: `depth` preallocated host buffer pairs.
        # acquire_stage() blocks when all are owned by in-flight swap-ins —
        # the double-buffering bound.
        shp = tier.block_shape
        self._stage_k = [np.zeros(shp, tier.dtype) for _ in range(depth)]
        self._stage_v = [np.zeros(shp, tier.dtype) for _ in range(depth)]
        self._free_stage: queue.Queue = queue.Queue()
        for i in range(depth):
            self._free_stage.put(i)

    # -- submission (caller thread) -----------------------------------------
    def submit_out(self, host_slot: int, dev_k, dev_v) -> None:
        """Queue a spill: device rows -> ``store[host_slot]``.  The D2H
        transfer is ENQUEUED here, on the caller's thread
        (``copy_to_host_async``) — that sequences it in the device stream
        before any later donated step can recycle pool buffers, which is
        what makes the worker's eventual ``device_get`` a pure collect of
        already-fetched bytes rather than a cross-thread read racing the
        compute stream."""
        for a in (dev_k, dev_v):
            if hasattr(a, "copy_to_host_async"):
                a.copy_to_host_async()
        self._submit(("out", host_slot, dev_k, dev_v))

    def acquire_stage(self) -> int:
        """Claim a staging buffer (blocks while all ``depth`` are in
        flight).  The caller fills it from the store and passes it to
        ``submit_in``; the worker releases it after upload."""
        return self._free_stage.get()

    def submit_in(self, flat_rows, stage: int) -> None:
        """Queue a swap-in: staging buffer ``stage`` -> device arrays on
        the ready list, destined for pool rows ``flat_rows``."""
        self._submit(("in", flat_rows, stage))

    def _submit(self, job) -> None:
        self._ensure_worker()
        with self._cond:
            self._raise_if_failed()
            self._pending += 1
        self._jobs.put(job)                # blocks at `depth` in flight

    # -- synchronization ----------------------------------------------------
    def drain(self) -> None:
        """Block until every submitted job has executed — the explicit
        drain point that keeps step order deterministic.  Re-raises a
        worker-thread failure here, on the caller's thread."""
        with self._cond:
            if self._pending and self.tracer.enabled:
                with self.tracer.span("serve.swap.drain", cat="serve",
                                      args={"pending": self._pending}):
                    self._wait_pending()
            else:
                self._wait_pending()
            self._raise_if_failed()

    def _wait_pending(self) -> None:  # requires-lock: _cond
        """Wait for pending jobs, robust to a dead worker: if the thread
        died with jobs outstanding (it can only exit between jobs, so this
        means it was killed externally), record the failure instead of
        waiting forever."""
        while self._pending:
            if self._thread is None or not self._thread.is_alive():
                if self._error is None:
                    self._error = RuntimeError(
                        f"swap worker died with {self._pending} "
                        f"job(s) pending")
                self._pending = 0
                break
            self._cond.wait(timeout=0.05)

    def pop_ready(self) -> list[tuple]:
        """Take ownership of the completed swap-ins ``(flat_rows, dev_k,
        dev_v)``, in submission order.  Separate from ``drain()`` so the
        tier's internal waits never swallow scatters the CACHE still owes
        its pools."""
        with self._cond:
            ready, self._ready = self._ready, []
        return ready

    def pop_failed(self) -> list:
        """Take ownership of the ``flat_rows`` of swap-ins whose upload
        failed — their target pool rows were never written (garbage).  The
        cache's degradation path preempts the owning requests so the rows
        are re-prefilled, never read."""
        with self._cond:
            failed, self._failed_in = self._failed_in, []
        return failed

    def release_stage(self, stage: int) -> None:
        """Return a staging buffer acquired for a swap-in that was never
        submitted (the submit itself failed)."""
        self._free_stage.put(stage)

    @property
    def in_flight(self) -> int:
        with self._cond:
            return self._pending

    def close(self, timeout: float = 10.0) -> None:
        """Drain and stop the worker (tests / long-lived drivers; the
        daemon thread dies with the process otherwise).  A pending worker
        failure is surfaced on EVERY path — including when the worker is
        already dead — never silently dropped; a join that times out is
        counted (``serve.swap.close_timeout``) instead of being mistaken
        for a clean stop."""
        try:
            if self._thread is not None and self._thread.is_alive():
                self.drain()
                self._jobs.put(None)
                self._thread.join(timeout=timeout)
                if self._thread.is_alive():
                    self.tier.metrics.inc("serve.swap.close_timeout")
                    if self.tracer.enabled:
                        self.tracer.instant("serve.swap.close_timeout",
                                            cat="serve",
                                            args={"timeout_s": timeout})
        finally:
            self._thread = None
            with self._cond:
                self._raise_if_failed()

    def abandon(self) -> None:
        """Degradation teardown: clear the failure state and detach without
        draining.  The tier is being dropped wholesale, so outstanding byte
        movement no longer matters; queued jobs (and the stop sentinel)
        still run in FIFO order on the worker, releasing any staging
        buffers they own."""
        with self._cond:
            self._error = None
            self._failed_in = []
            self._abandoned = True
        try:
            self._jobs.put_nowait(None)
        except queue.Full:
            pass                          # worker drains the queue, then the
        #                                   next close()/sentinel stops it

    def _raise_if_failed(self) -> None:  # requires-lock: _cond
        if self._error is None:
            return
        if getattr(self, "_abandoned", False):
            return                        # post-degradation failures are
        #                                   noise: the tier is already gone
        # NOT consumed: the failure keeps raising until abandon() — a debug
        # drain (check_consistent) must not swallow the signal before the
        # cache's pool-read barrier converts it into degradation
        raise SwapWorkerError("KV swap worker failed") from self._error

    # -- worker -------------------------------------------------------------
    def _ensure_worker(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run, name="kv-swap", daemon=True)
            self._thread.start()

    def _run(self) -> None:
        while True:
            job = self._jobs.get()
            if job is None:
                return
            try:
                self._execute(job)
            except BaseException as e:  # noqa: BLE001 — surfaced at drain
                with self._cond:
                    self._error = e
                    if job[0] == "in":
                        # the upload never ran: the target pool rows hold
                        # garbage — record them for the degradation path
                        self._failed_in.append(job[1])
            finally:
                with self._cond:
                    self._pending -= 1
                    self._cond.notify_all()

    def _execute(self, job) -> None:
        tier, tr = self.tier, self.tracer
        if job[0] == "out":
            _, slot, dev_k, dev_v = job
            if self.faults is not None:
                self.faults.check("swap.out")
            span = (tr.span("serve.swap.out", cat="serve",
                            args={"host_slot": slot,
                                  "bytes": tier.block_bytes})
                    if tr.enabled else NULL_SPAN)
            with span:
                # device_get: jax array -> the store's preallocated rows
                tier.store_k[slot][...] = np.asarray(dev_k)
                tier.store_v[slot][...] = np.asarray(dev_v)
            with self._cond:
                n = tier._inflight_out.get(slot, 0) - 1
                if n <= 0:
                    tier._inflight_out.pop(slot, None)
                else:
                    tier._inflight_out[slot] = n
        else:
            _, flat_rows, stage = job
            try:
                if self.faults is not None:
                    self.faults.check("swap.in")
                span = (tr.span("serve.swap.in", cat="serve",
                                args={"bytes": tier.block_bytes})
                        if tr.enabled else NULL_SPAN)
                with span:
                    # device_put + MATERIALIZED copy: on CPU backends a
                    # plain device_put may alias the numpy staging buffer
                    # (zero-copy) or read it lazily under async dispatch,
                    # and the buffer is reused the moment we release it —
                    # so copy through a device-side op and block until it
                    # has actually executed before handing the stage back
                    dev_k = jnp.array(self._stage_k[stage], copy=True)
                    dev_v = jnp.array(self._stage_v[stage], copy=True)
                    jax.block_until_ready((dev_k, dev_v))
                with self._cond:
                    self._ready.append((flat_rows, dev_k, dev_v))
            finally:
                # the staging buffer goes back even when the upload fails —
                # a leaked stage would deadlock acquire_stage() forever
                self._free_stage.put(stage)


class HostKVTier:
    """Host-RAM block store + the prefix index's second level.

    ``put``/``take``/``invalidate``/``flush`` mutate the index and slot
    bookkeeping synchronously (deterministic, caller-thread); the byte
    movement behind ``put`` and ``take``->``submit_in`` is the
    ``SwapEngine``'s async business.  Capacity is ``num_blocks`` host
    slots; when full, ``put`` evicts the least-recently-used key — the
    host tier is a cache over recomputable state, so dropping is always
    safe (the victim falls back to recompute-on-readmission).
    """

    def __init__(self, cfg: ModelConfig, *, num_blocks: int, block_size: int,
                 metrics=None, tracer=None, staging: int = 2, faults=None):
        if num_blocks < 1:
            raise ValueError(f"host tier needs >= 1 block, got {num_blocks}")
        n, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.dtype = np.dtype(L.cdtype(cfg))
        self.block_shape = (n, block_size, kv, hd)
        self.store_k = np.zeros((num_blocks, *self.block_shape), self.dtype)
        self.store_v = np.zeros_like(self.store_k)
        self.block_bytes = int(self.store_k[0].nbytes * 2)  # k + v
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # LRU index: oldest key first; lookup/put refresh recency
        self._index: OrderedDict[bytes, int] = OrderedDict()
        self._slot_key: dict[int, bytes] = {}
        self._free: deque[int] = deque(range(num_blocks))
        # host slots with a spill still in flight:
        # take() must not read the store before the worker wrote it
        self._inflight_out: dict[int, int] = {}  # guarded-by: swap._cond
        self.disabled = False             # set by disable() after a worker
        #                                   failure — the tier stops caching
        self.swap = SwapEngine(self, depth=staging, tracer=tracer,
                               faults=faults)

    def __len__(self) -> int:
        return len(self._index)

    @property
    def host_bytes(self) -> int:
        return int(self.store_k.nbytes + self.store_v.nbytes)

    # -- index --------------------------------------------------------------
    def lookup(self, key: bytes) -> int | None:
        """Host slot caching exactly this prefix, or None.  A hit counts as
        a use for LRU eviction ordering."""
        slot = self._index.get(key)
        if slot is not None:
            self._index.move_to_end(key)
        return slot

    def put(self, key: bytes, dev_k, dev_v) -> None:
        """Spill one device block's rows under ``key`` (async device_get).
        No-op when the key is already host-resident — identical content
        (same tokens, same weights) is already down here.  When the store
        is full the LRU key is evicted: it falls all the way out of the
        tiered index and its next use pays recompute, exactly the pre-tier
        behavior."""
        if self.disabled:
            return
        if key in self._index:
            self._index.move_to_end(key)
            return
        if self._free:
            slot = self._free.popleft()
        else:
            _, slot = self._index.popitem(last=False)   # LRU victim
            del self._slot_key[slot]
            self.metrics.inc("serve.swap.host_evictions")
        self._index[key] = slot
        self._slot_key[slot] = key
        with self.swap._cond:
            self._inflight_out[slot] = self._inflight_out.get(slot, 0) + 1
        # counters tick on the caller thread so stats stay deterministic
        self.metrics.inc("serve.swap.out_blocks")
        self.metrics.inc("serve.swap.out_bytes", self.block_bytes)
        self.swap.submit_out(slot, dev_k, dev_v)

    def take(self, key: bytes) -> int | None:
        """Claim ``key``'s content for a swap-in: drop the index entry,
        copy the slot into a staging buffer, free the slot.  Returns the
        staging buffer id (pass to ``swap.submit_in``), or None if the key
        is not host-resident (evicted since it was matched)."""
        slot = self._index.pop(key, None)
        if slot is None:
            return None
        del self._slot_key[slot]
        with self.swap._cond:
            busy = slot in self._inflight_out
        if busy:
            # our own spill has not landed yet (swapped back in the same
            # breath) — the only cross-thread wait in the design
            self.swap.drain()
        stage = self.swap.acquire_stage()
        self.swap._stage_k[stage][...] = self.store_k[slot]
        self.swap._stage_v[stage][...] = self.store_v[slot]
        self._free.append(slot)
        return stage

    def invalidate(self, key: bytes) -> None:
        """Drop ``key`` if host-resident (the device tier just indexed the
        same prefix — one tier owns a key at a time).  No drain needed: a
        pending spill into the freed slot completes harmlessly, and any
        LATER spill reusing the slot is ordered after it by the worker's
        FIFO queue."""
        slot = self._index.pop(key, None)
        if slot is not None:
            del self._slot_key[slot]
            self._free.append(slot)

    def flush(self) -> None:
        """Forget every hosted block (weights changed: stale-weights KV
        must never satisfy a match).  Completed swap-ins on the ready list
        survive — they belong to requests admitted under the OLD weights
        that are still running, same as device allocations surviving
        ``flush_index``."""
        self.swap.drain()
        self._index.clear()
        self._slot_key.clear()
        self._free = deque(range(self.num_blocks))

    def disable(self) -> None:
        """Swap-failure degradation: drop the whole host index and stop
        caching.  Every hosted prefix is forgotten — the tier is a cache
        over recomputable state, so dropping is always safe (future
        readmissions pay recompute, exactly the tier-off behavior) — and
        the abandoned worker is sent its stop sentinel without waiting."""
        self.disabled = True
        self._index.clear()
        self._slot_key.clear()
        self._free = deque(range(self.num_blocks))
        with self.swap._cond:
            self._inflight_out.clear()
        self.swap.abandon()

    # -- debugging ----------------------------------------------------------
    def check_consistent(self) -> None:
        """Slot/key maps mirror, every slot is exactly one of used|free,
        and nothing is in flight after a drain."""
        self.swap.drain()
        assert len(self._index) == len(self._slot_key), "index/slot mismatch"
        for key, slot in self._index.items():
            assert self._slot_key.get(slot) == key, (slot, key)
        used, free = set(self._slot_key), set(self._free)
        assert not (used & free), f"host slot both used and free: {used & free}"
        assert len(free) == len(self._free), "duplicate free host slots"
        assert len(used) + len(free) == self.num_blocks, "host slot leak"
        with self.swap._cond:
            assert not self._inflight_out, "in-flight spill after drain"

    def close(self) -> None:
        self.swap.close()
