"""End-to-end driver: GRPO-train a small model on the pattern rule-reward
task until the reward climbs (the paper's Figure 8 at CPU scale).

Demonstrates: the full training loop — graph-declared GRPO (or DAPO with
``--algorithm dapo``) actually LEARNING on the rule-reward task, not just
executing one iteration.

Expected output: the graph declaration, then one ``[it] reward=... (best
...) loss=... kl=...`` line per iteration; the first-5 vs last-5 mean
reward comparison at the end must improve (asserted).  ``--log-json PATH``
additionally writes the per-iteration dicts.  A few minutes on CPU.

    PYTHONPATH=src python examples/grpo_train.py [--iterations 40]
"""
import argparse
import json

from repro.configs.base import ModelConfig, RLConfig
from repro.core.trainer import GRPOTrainer
from repro.data.prompts import PromptDataset, pattern_task

# ~8M-param llama-family model — big enough to learn, small enough for CPU.
CFG = ModelConfig(
    name="grpo-demo-8m", arch_type="dense", num_layers=2, d_model=256,
    vocab_size=512, num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024,
    rope_theta=10_000.0, dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=40)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--algorithm", default="grpo", choices=["grpo", "dapo"])
    ap.add_argument("--log-json", default=None)
    args = ap.parse_args()

    rl = RLConfig(algorithm=args.algorithm, num_generations=8,
                  max_prompt_len=12, max_response_len=8, lr=3e-4,
                  kl_coef=1e-3, temperature=1.0)
    ds = PromptDataset(pattern_task(), max_prompt_len=12, seed=0)
    tr = GRPOTrainer(CFG, rl, ds, num_nodes=4, seed=0, microbatch=64)
    print(tr.graph.describe(), "\n")

    log, best = [], 0.0
    for it in range(args.iterations):
        st = tr.iteration(args.global_batch)
        best = max(best, st.reward_mean)
        log.append({"iteration": it, "reward": st.reward_mean,
                    "loss": st.loss, "kl": st.kl})
        print(f"[{it:3d}] reward={st.reward_mean:.3f} (best {best:.3f}) "
              f"loss={st.loss:8.4f} kl={st.kl:.5f}")
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump(log, f, indent=1)
    first = sum(r["reward"] for r in log[:5]) / 5
    last = sum(r["reward"] for r in log[-5:]) / 5
    print(f"\nmean reward: first-5 {first:.3f} -> last-5 {last:.3f}")
    assert last > first, "reward did not improve"
    print("reward improved — RL loop verified end-to-end")


if __name__ == "__main__":
    main()
