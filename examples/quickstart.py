"""Quickstart: build a model from the zoo, run one GRPO iteration through
the full MindSpeed-RL dataflow (transfer dock + allgather-swap), print what
moved where.

Demonstrates: the minimal trainer entry point — one ``GRPOTrainer``
iteration wired through the dock's data+metadata planes and the resharding
flow, on a CPU smoke config.

Expected output: the arch line, then one block of iteration stats (reward
mean±std, KL, loss — all finite) and the dispatch-ledger snapshot
(internode/intranode bytes, per-warehouse load, modeled dispatch time).
Runs in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import get_smoke_config
from repro.configs.base import RLConfig
from repro.core.trainer import GRPOTrainer
from repro.data.prompts import PromptDataset, pattern_task


def main():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    rl = RLConfig(num_generations=4, max_prompt_len=16, max_response_len=16,
                  lr=2e-4)
    ds = PromptDataset(pattern_task(), max_prompt_len=16, seed=0)
    trainer = GRPOTrainer(cfg, rl, ds, num_nodes=4, seed=0)

    print(f"arch={cfg.name}  layers={cfg.num_layers}  d_model={cfg.d_model}")
    stats = trainer.iteration(global_batch=8)

    print(f"\nreward        : {stats.reward_mean:.3f} ± {stats.reward_std:.3f}")
    print(f"loss          : {stats.loss:.4f}   kl: {stats.kl:.5f}")
    print(f"stage times   : gen {stats.gen_time:.1f}s | infer "
          f"{stats.infer_time:.1f}s | update {stats.update_time:.1f}s")
    print("\n-- sample flow (transfer dock) --")
    for k, v in stats.dispatch.items():
        print(f"  {k}: {v}")
    print("\n-- resharding flow (allgather-swap) --")
    for label, b in stats.reshard["timeline"]:
        print(f"  {label}: {b / 1e6:.1f} MB/device")
    print(f"  modeled swap time: "
          f"{stats.reshard['modeled_swap_time_s'] * 1e3:.2f} ms @ 50 GB/s")


if __name__ == "__main__":
    main()
