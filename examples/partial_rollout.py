"""Partial rollout (paper Table 2), serving-backed: long-tail sequences are
split across iterations by a per-request token budget.  Each iteration the
generation node submits every pending sequence to the continuous-batching
``ServingEngine`` — carried-over ones mid-sequence, re-matched against the
prefix cache and re-prefilled like a preemption refill — and finished
samples stream into the transfer dock the moment they complete, so
downstream stages start before the drain ends.

Demonstrates: the budgeted generate/suspend/resume lifecycle across 4
trainer iterations, the ``complete_groups`` gate holding updates until
whole GRPO groups exist, and that per-request budgets never touch the
engine-wide ``max_new`` (asserted).

Expected output: the engine banner, then one ``iter k: pending=... updated
(groups complete)=... reward=... loss=... decode steps=...`` line per
iteration — pending counts shrink as budgets accumulate — and the closing
engine-cap assertion message.  ~2 minutes on CPU.

    PYTHONPATH=src python examples/partial_rollout.py
"""

from repro.configs import get_smoke_config
from repro.configs.base import RLConfig
from repro.core.partial import PartialRolloutTrainer
from repro.data.prompts import PromptDataset, pattern_task


def main():
    cfg = get_smoke_config("yi-6b").replace(dtype="float32", remat=False)
    rl = RLConfig(num_generations=2, max_prompt_len=16, max_response_len=24,
                  lr=2e-4, partial_rollout=True, serve_max_slots=4,
                  serve_block_size=8)
    ds = PromptDataset(pattern_task(), max_prompt_len=16, seed=0)
    trainer = PartialRolloutTrainer(cfg, rl, ds, budget=8, num_nodes=4,
                                    seed=0)
    eng = trainer.actor.engine
    print(f"arch={cfg.name}  budget=8 tok/iter  response cap="
          f"{rl.max_response_len}  engine={type(eng).__name__} "
          f"({rl.serve_max_slots} slots)")

    for it in range(4):
        stats = trainer.iteration(global_batch=4)
        consumed = len(trainer.dock.controllers["actor_update"].consumed)
        print(f"iter {it}: pending={trainer.pending_partials:>2}  "
              f"updated(groups complete)={consumed:>2}  "
              f"reward={stats.reward_mean:+.3f}  loss={stats.loss:.4f}  "
              f"decode steps={eng.steps}")
    # the engine-wide cap was never clobbered by the budgeted requests
    assert eng.max_new == rl.max_response_len
    print("\nper-request budgets left the engine cap untouched "
          f"(max_new={eng.max_new}); resumes re-prefill through the same "
          "path as recompute preemption.")


if __name__ == "__main__":
    main()
