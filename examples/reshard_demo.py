"""Resharding-flow demo: the paper's Figure 5 walked step by step on a real
model — naive reshard vs allgather-swap, with the per-device memory timeline
and the modeled swap durations printed side by side.

Demonstrates: why naive update->generation resharding spikes device memory
(full-model allgather alongside the resident shard) and how the
allgather-swap's D2H/H2D staging flattens the peak; ``--paper-two-step``
runs the literal Figure-5 temp-buffer variant.

Expected output: one ``== naive reshard ==`` / ``== allgather-swap ==``
block each with a per-phase MB/device memory timeline; naive ends with its
Eq. 3 redundancy line, allgather-swap with the modeled D2H swap time and a
bit-exact H2D swap-back verification.  ~1 minute on CPU.

    PYTHONPATH=src python examples/reshard_demo.py --arch mixtral-8x7b
"""
import argparse

import jax

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.resharding import Resharder
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.sharding import param_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=ALL_ARCHS)
    ap.add_argument("--paper-two-step", action="store_true",
                    help="literal Figure-5 temp-buffer allgather")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))
    t = param_specs(cfg, params, mesh, stage="train")
    g = param_specs(cfg, params, mesh, stage="gen", gen_mode="tp")

    for use_swap in (False, True):
        name = "allgather-swap" if use_swap else "naive reshard"
        rs = Resharder(mesh, t, g, use_swap=use_swap,
                       paper_two_step=args.paper_two_step)
        gen, stash, led = rs.to_generation(params)
        print(f"\n== {name} ==")
        for label, b in led.timeline():
            print(f"  {label:35s} {b / 1e6:9.1f} MB/device")
        if use_swap:
            print(f"  D2H swap: {led.d2h_bytes / 1e6:.1f} MB "
                  f"(modeled {led.swap_time_s * 1e3:.2f} ms @ 50 GB/s)")
            back, led = rs.to_update(stash, led)
            import numpy as np
            for k_a, k_b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
                np.testing.assert_array_equal(np.asarray(k_a), np.asarray(k_b))
            print("  H2D swap-back verified bit-exact")
        else:
            print(f"  redundant update partition held on device: "
                  f"{rs.redundancy_bytes(params) / 1e6:.1f} MB "
                  f"(Eq. 3 redundancy)")


if __name__ == "__main__":
    main()
