"""Request-loop serving demo: continuous batching over the paged KV cache.

Loads (or inits) a model in the GENERATION layout produced by the resharding
flow, then drives the ``ServingEngine`` like an online server: requests
arrive over several "ticks", each engine step admits what fits (prefix-
matching resident prompt-head blocks), decodes one token for every active
slot, and evicts finished sequences immediately — freed slots refill from
the queue with no batch barrier.

Demonstrates: the online ``submit()``/``step()`` API under staggered
arrivals — admission, refill, and (with ``--blocks``) recompute preemption,
which ``--host-tier`` upgrades to SWAP preemption: a victim's reclaimed KV
blocks spill to a host-RAM tier and stream back on re-admission instead of
being re-prefilled (a swap-counter line reports the traffic).

Expected output: the reshard banner, an aggregate line (requests / tokens /
tok/s / engine steps) with p50/p99 latency, then one row per request —
rid, prompt -> decoded text, token count, latency, preemption count.
~1 minute on CPU.

    PYTHONPATH=src python examples/serve.py --arch yi-6b

Use ``--slots`` smaller than the request count to watch refill in action,
``--blocks`` to shrink the KV pool until preemption kicks in, and then
``--host-tier N`` to watch the same starved pool swap instead of
recompute.  ``--trace out.json`` exports a Chrome trace of the run
(chrome://tracing; summarize with tools/trace_report.py — the
``serve.swap.out``/``serve.swap.in`` spans are the async copy engine).
"""
import argparse
import time

import jax

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.resharding import Resharder
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.serve.engine import ServingEngine
from repro.sharding import param_specs

REQUESTS = [
    ("hello world", 24),
    ("repeat a:", 8),
    ("the quick brown fox", 32),
    ("12+34=", 6),
    ("tell me a story", 40),
    ("ok", 4),
    ("jumps over the lazy dog", 16),
    ("2*3=", 6),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=ALL_ARCHS)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--blocks", type=int, default=0,
                    help="KV pool blocks (0 = enough for all slots)")
    ap.add_argument("--host-tier", type=int, default=0, metavar="N",
                    help="host-RAM KV tier capacity in blocks (0 = off); "
                    "turns recompute preemption into swap preemption")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Chrome trace of the serving run")
    ap.add_argument("--greedy", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype="float32", remat=False)
    assert cfg.arch_type in ("dense", "moe"), \
        "serve demo uses text prompts; pick a dense or moe arch"
    tok = ByteTokenizer()
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))

    # move weights into the generation layout (the serving-side of the
    # resharding flow; on one device this is a no-op data-wise)
    mesh = make_mesh((1, 1), ("data", "model"))
    t = param_specs(cfg, params, mesh, stage="train")
    g = param_specs(cfg, params, mesh, stage="gen", gen_mode="tp")
    gen_params, _, led = Resharder(mesh, t, g, use_swap=True).to_generation(
        params)
    print(f"resharded to generation layout "
          f"(D2H released {led.d2h_bytes / 1e6:.1f} MB/device)")

    from repro.obs import Tracer
    tracer = Tracer(enabled=bool(args.trace))
    max_seq = max(len(tok.encode(r)) + n for r, n in REQUESTS)
    engine = ServingEngine(
        cfg, max_new=48, eos_id=tok.eos_id, pad_id=tok.pad_id,
        greedy=args.greedy, max_slots=args.slots,
        block_size=args.block_size, max_seq_len=max_seq,
        num_blocks=args.blocks or None,
        host_tier_blocks=args.host_tier, tracer=tracer)

    # online loop: two requests arrive per tick, the engine never waits for
    # a full batch to form
    outs, rid2text = [], {}
    t0 = time.perf_counter()
    pending = list(REQUESTS)
    while pending or not engine.sched.idle:
        for text, max_new in pending[:2]:
            rid = engine.submit(tok.encode(text), max_new=max_new)
            rid2text[rid] = text
        pending = pending[2:]
        outs.extend(engine.step(gen_params))
    dt = time.perf_counter() - t0

    new_tokens = sum(len(o.gen) for o in outs)
    st = engine.stats()     # registry-backed counters + latency percentiles
    print(f"\nserved {st['finished']} requests / {new_tokens} tokens in "
          f"{dt:.2f}s ({new_tokens / dt:.1f} tok/s) over {st['steps']} "
          f"engine steps")
    print(f"latency p50 {st['latency_s']['p50'] * 1e3:.0f} ms, "
          f"p99 {st['latency_s']['p99'] * 1e3:.0f} ms; "
          f"ttft p50 {st['ttft_s']['p50'] * 1e3:.0f} ms")
    if args.host_tier:
        print(f"host tier: {st['preempt_swap']} swap / "
              f"{st['preempt_recompute']} recompute preemptions; "
              f"swapped out {st['swap_out_blocks']} blocks "
              f"({st['swap_out_bytes'] / 1e6:.1f} MB), in "
              f"{st['swap_in_blocks']} blocks "
              f"({st['swap_in_bytes'] / 1e6:.1f} MB); "
              f"{st['host_resident_blocks']}/{st['host_tier_blocks']} "
              f"host blocks resident")
    for o in sorted(outs, key=lambda o: o.rid):
        txt = tok.decode(o.gen)
        pre = f" ({o.preemptions} preemptions)" if o.preemptions else ""
        print(f"  [{o.rid}] {rid2text[o.rid]!r} -> {txt!r}  "
              f"{len(o.gen)} tok, {o.latency_s * 1e3:.0f} ms{pre}")
    engine.close()
    if args.trace:
        print(f"trace written to {tracer.export(args.trace)}")


if __name__ == "__main__":
    main()
