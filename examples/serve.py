"""Batched serving example: load (or init) a model in the GENERATION layout
produced by the resharding flow and serve batched requests through the
rollout engine — the generation-stage half of the system, standalone.

    PYTHONPATH=src python examples/serve.py --arch mamba2-1.3b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.resharding import Resharder
from repro.core.rollout import RolloutEngine
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.sharding import param_specs

REQUESTS = [
    "hello world",
    "repeat a:",
    "the quick brown fox",
    "12+34=",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=ALL_ARCHS)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--greedy", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype="float32", remat=False)
    assert cfg.arch_type not in ("vlm", "audio"), \
        "serve demo uses text prompts; pick a text arch"
    tok = ByteTokenizer()
    model = build_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))

    # move weights into the generation layout (the serving-side of the
    # resharding flow; on one device this is a no-op data-wise)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    t = param_specs(cfg, params, mesh, stage="train")
    g = param_specs(cfg, params, mesh, stage="gen", gen_mode="tp")
    gen_params, _, led = Resharder(mesh, t, g, use_swap=True).to_generation(
        params)
    print(f"resharded to generation layout "
          f"(D2H released {led.d2h_bytes / 1e6:.1f} MB/device)")

    engine = RolloutEngine(cfg, max_new=args.max_new, eos_id=tok.eos_id,
                           pad_id=tok.pad_id, greedy=args.greedy)
    ids = [tok.encode(r) for r in REQUESTS]
    batch = tok.pad_batch(ids, max(len(i) for i in ids))
    t0 = time.perf_counter()
    res = engine.generate(gen_params, batch, jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    new_tokens = int(res.lengths.sum())
    print(f"served {len(REQUESTS)} requests, {new_tokens} tokens "
          f"in {dt:.2f}s ({new_tokens / dt:.1f} tok/s)")
    for r, row, n in zip(REQUESTS, res.tokens, res.lengths):
        out = tok.decode(row[batch.shape[1]:batch.shape[1] + n])
        print(f"  {r!r} -> {out!r}")


if __name__ == "__main__":
    main()
