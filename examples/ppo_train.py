"""PPO / PF-PPO end-to-end example (the paper's other algorithm family).

Demonstrates: PPO as a graph EDIT of GRPO (critic values on the inference
node, token-level GAE advantages) over the identical executor/dock/
resharder; ``--pf`` adds PF-PPO's rank filtration in front of the update.

Expected output: the graph declaration, then one ``[it] reward=...
loss=... |kl|=...`` line per iteration and a first-3 vs last-3 mean-reward
comparison; rewards trend upward over the default 20 iterations.  A few
minutes on CPU.

    PYTHONPATH=src python examples/ppo_train.py [--pf] [--iterations 20]
"""
import argparse

from repro.configs.base import ModelConfig, RLConfig
from repro.core.ppo_trainer import PPOTrainer
from repro.data.prompts import PromptDataset, pattern_task

CFG = ModelConfig(
    name="ppo-demo-8m", arch_type="dense", num_layers=2, d_model=256,
    vocab_size=512, num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024,
    rope_theta=10_000.0, dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--pf", action="store_true", help="PF-PPO filtration")
    args = ap.parse_args()

    rl = RLConfig(max_prompt_len=12, max_response_len=8, lr=3e-4,
                  kl_coef=1e-3, gae_lambda=0.95)
    ds = PromptDataset(pattern_task(), max_prompt_len=12, seed=0)
    tr = PPOTrainer(CFG, rl, ds, pf_filter=args.pf, num_nodes=4, seed=0)
    print(tr.graph.describe(), "\n")

    rewards = []
    for it in range(args.iterations):
        st = tr.iteration(args.global_batch)
        rewards.append(st.reward_mean)
        print(f"[{it:3d}] reward={st.reward_mean:.3f} loss={st.loss:8.4f} "
              f"|kl|={st.kl:.5f}")
    first = sum(rewards[:3]) / 3
    last = sum(rewards[-3:]) / 3
    print(f"\nmean reward: first-3 {first:.3f} -> last-3 {last:.3f}")


if __name__ == "__main__":
    main()
