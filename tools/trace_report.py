#!/usr/bin/env python3
"""Summarize a repro Chrome-trace JSON (train.py --trace / Tracer.export).

Prints, from the ``traceEvents`` stream alone (no repro imports, so it works
on any machine the trace file lands on):

  * per-span-name duration table (count, total/mean/max ms) for "X" events;
  * per-graph-node dispatch table (cat == "graph" spans: cluster node,
    dispatches, samples, fused/streamed dispatch counts);
  * final value of every counter series ("C" events, e.g. dock.bytes).

``--expect a,b,c`` asserts that every named graph node appears as a
``stage.<name>`` span — CI's trace smoke uses it to prove the whole GRPO
graph made it into the trace.  ``--expect-spans a,b,c`` asserts plain span
names (any category, "X" events) — CI's serving smoke uses it to prove the
host-tier swap engine traced its copies (``serve.swap.out`` /
``serve.swap.in``).  Exit status: 0 ok, 1 empty/missing.

Usage:
    python tools/trace_report.py run.trace.json [--expect n1,n2,...]
                                 [--expect-spans s1,s2,...]
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


def load_events(path: str) -> list[dict]:
    with open(path) as f:
        doc = json.load(f)
    # Chrome trace allows both the object form and a bare event array
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise SystemExit(f"{path}: traceEvents is not a list")
    return events


def span_table(events: list[dict]) -> dict[str, dict]:
    spans: dict[str, dict] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        s = spans.setdefault(ev["name"], {"count": 0, "total_ms": 0.0,
                                          "max_ms": 0.0,
                                          "cat": ev.get("cat", "")})
        dur_ms = float(ev.get("dur", 0.0)) / 1e3
        s["count"] += 1
        s["total_ms"] += dur_ms
        s["max_ms"] = max(s["max_ms"], dur_ms)
    return spans


def graph_table(events: list[dict]) -> dict[str, dict]:
    nodes: dict[str, dict] = {}
    for ev in events:
        if ev.get("ph") != "X" or ev.get("cat") != "graph":
            continue
        args = ev.get("args") or {}
        name = args.get("node") or ev["name"].removeprefix("stage.")
        n = nodes.setdefault(name, {"cluster_node": args.get("cluster_node"),
                                    "dispatches": 0, "samples": 0,
                                    "fused": 0, "streamed": 0})
        n["dispatches"] += 1
        n["samples"] += int(args.get("samples", 0))
        n["fused"] += bool(args.get("fused"))
        n["streamed"] += bool(args.get("stream"))
    return nodes


def counter_finals(events: list[dict]) -> dict[str, dict]:
    finals: dict[str, dict] = defaultdict(dict)
    for ev in events:              # events are ts-sorted by the exporter,
        if ev.get("ph") != "C":    # so last write per series wins
            continue
        finals[ev["name"]].update(ev.get("args") or {})
    return dict(finals)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome-trace JSON file")
    ap.add_argument("--expect", default=None, metavar="N1,N2,...",
                    help="comma-separated graph-node names that must appear "
                    "as stage.<name> spans (exit 1 listing any missing)")
    ap.add_argument("--expect-spans", default=None, metavar="S1,S2,...",
                    help="comma-separated span names that must appear as "
                    "duration events (exit 1 listing any missing)")
    args = ap.parse_args(argv)

    events = load_events(args.trace)
    if not events:
        print(f"{args.trace}: no trace events", file=sys.stderr)
        return 1

    spans = span_table(events)
    print(f"{args.trace}: {len(events)} events, {len(spans)} span names\n")
    print(f"{'span':<28}{'cat':<10}{'count':>7}{'total_ms':>11}"
          f"{'mean_ms':>10}{'max_ms':>10}")
    for name in sorted(spans, key=lambda n: -spans[n]["total_ms"]):
        s = spans[name]
        print(f"{name:<28}{s['cat']:<10}{s['count']:>7}"
              f"{s['total_ms']:>11.2f}{s['total_ms'] / s['count']:>10.2f}"
              f"{s['max_ms']:>10.2f}")

    nodes = graph_table(events)
    if nodes:
        print(f"\n{'graph node':<22}{'cluster':>8}{'dispatches':>11}"
              f"{'samples':>9}{'fused':>7}{'streamed':>9}")
        for name in sorted(nodes):
            n = nodes[name]
            print(f"{name:<22}{str(n['cluster_node']):>8}"
                  f"{n['dispatches']:>11}{n['samples']:>9}"
                  f"{n['fused']:>7}{n['streamed']:>9}")

    finals = counter_finals(events)
    if finals:
        print("\ncounter final values:")
        for name in sorted(finals):
            series = ", ".join(f"{k}={v}" for k, v in
                               sorted(finals[name].items()))
            print(f"  {name}: {series}")

    if args.expect:
        want = [w for w in (p.strip() for p in args.expect.split(",")) if w]
        missing = [w for w in want if w not in nodes]
        if missing:
            print(f"\nMISSING graph nodes (no stage.<name> span): "
                  f"{missing}", file=sys.stderr)
            return 1
        print(f"\nall {len(want)} expected graph nodes present")
    if args.expect_spans:
        want = [w for w in
                (p.strip() for p in args.expect_spans.split(",")) if w]
        missing = [w for w in want if w not in spans]
        if missing:
            print(f"\nMISSING spans: {missing}", file=sys.stderr)
            return 1
        print(f"all {len(want)} expected spans present")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
