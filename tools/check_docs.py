#!/usr/bin/env python
"""Docs link + command checker (CI `docs` job; pure stdlib, no jax).

Keeps the documentation from rotting as the tree moves underneath it:

  * LINKS — every relative markdown link target in README.md and docs/*.md
    must exist on disk (anchors stripped; http(s)/mailto skipped).
  * COMMANDS — every ``python -m <module>`` quoted in those files must
    resolve to a real module file under the repo root or ``src/`` (checked
    on the filesystem, so nothing heavyweight is imported), and every
    ``python <path>.py`` must name an existing file.  CI separately
    EXECUTES the load-bearing quoted invocations (pytest, bench_dispatch,
    bench_partial_stream, bench_serving decode/prefix) as its own steps;
    this script asserts those steps and the docs agree on the commands.

Run from the repo root:  python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
MOD_RE = re.compile(r"python\s+-m\s+([A-Za-z0-9_.]+)")
FILE_RE = re.compile(r"python\s+([A-Za-z0-9_./-]+\.py)")

# commands CI must both execute (workflow steps) and document
CI_EXECUTED = [
    "benchmarks.bench_dispatch",
    "benchmarks.bench_partial_stream",
    "benchmarks.bench_serving",
    "benchmarks.run",                  # bench-artifacts steps (BENCH_*.json:
    #                                    serving, sampling, swap)
]

# scripts CI must both execute and document (same agreement contract)
CI_SCRIPTS = [
    "tools/trace_report.py",           # trace-smoke step (Perfetto export)
    "examples/serve.py",               # serve-demo smoke (host-tier swap)
]

# docs that must exist by name (load-bearing: other checks reference them)
REQUIRED_DOCS = [
    "docs/ARCHITECTURE.md",
    "docs/observability.md",
    "docs/analysis.md",
    "docs/resilience.md",              # FLT001's fault-site catalog
]


def doc_files() -> list[Path]:
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def module_exists(mod: str) -> bool:
    rel = Path(*mod.split("."))
    for base in (ROOT, ROOT / "src"):
        if (base / rel).with_suffix(".py").exists():
            return True
        if (base / rel / "__init__.py").exists():
            return True
    # not repo code: accept installed third-party/stdlib entry points
    # (e.g. `python -m pytest`) via a metadata-only spec lookup
    try:
        import importlib.util

        return importlib.util.find_spec(mod.split(".")[0]) is not None
    except (ImportError, ValueError):
        return False


def check_file(path: Path) -> list[str]:
    errors = []
    text = path.read_text()
    rel = path.relative_to(ROOT)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        dest = (path.parent / target.split("#", 1)[0]).resolve()
        if not dest.exists():
            errors.append(f"{rel}: broken link -> {target}")
    for mod in MOD_RE.findall(text):
        mod = mod.strip(".")
        if not mod:                    # prose placeholder like `python -m ...`
            continue
        if not module_exists(mod):
            errors.append(f"{rel}: quoted module does not resolve -> "
                          f"python -m {mod}")
    for script in FILE_RE.findall(text):
        if not (ROOT / script).exists():
            errors.append(f"{rel}: quoted script missing -> python {script}")
    return errors


def check_ci_agreement() -> list[str]:
    errors = []
    wf = ROOT / ".github" / "workflows" / "ci.yml"
    ci = wf.read_text() if wf.exists() else ""
    docs = "\n".join(p.read_text() for p in doc_files())
    for mod in CI_EXECUTED:
        if mod not in ci:
            errors.append(f"ci.yml no longer executes documented smoke "
                          f"`python -m {mod}`")
        if mod not in docs and mod.replace(".", "/") not in docs:
            errors.append(f"CI executes `python -m {mod}` but no doc "
                          f"mentions it")
    for script in CI_SCRIPTS:
        if script not in ci:
            errors.append(f"ci.yml no longer executes documented script "
                          f"`python {script}`")
        if script not in docs:
            errors.append(f"CI executes `python {script}` but no doc "
                          f"mentions it")
    return errors


def main() -> int:
    errors = []
    files = doc_files()
    if len(files) < 4:                 # README + ARCHITECTURE + serving + obs
        errors.append(f"expected README.md plus docs/*.md, found only "
                      f"{[str(f.relative_to(ROOT)) for f in files]}")
    for req in REQUIRED_DOCS:
        if not (ROOT / req).exists():
            errors.append(f"required doc missing: {req}")
    for f in files:
        errors.extend(check_file(f))
    errors.extend(check_ci_agreement())
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} doc problem(s)")
        return 1
    print(f"checked {len(files)} files: links ok, quoted commands resolve, "
          f"CI smoke commands documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
