"""Repo tooling: docs checker, trace reporter, contract analyzer.

``check_docs.py`` and ``trace_report.py`` are standalone scripts;
``tools.analyze`` is a package (``python -m tools.analyze``).
"""
