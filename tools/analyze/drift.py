"""Knob/counter drift pass: config fields and telemetry names stay
documented.

* **DRF001** — every ``RLConfig`` field must be REACHABLE: either wired
  in ``src/repro/launch/train.py`` (a CLI flag or the ``RLConfig(...)``
  construction) or mentioned by name in ``README.md``/``docs/*.md``.  A
  field neither place is a knob nobody can discover — the drift this
  repo actually accumulated before this pass existed (15 fields).
* **DRF002** — every literal ``serve.*``/``dock.*``/``graph.*`` name
  emitted through the telemetry layer
  (``MetricsRegistry.inc/observe/set/set_max``,
  ``Tracer.span/instant/counter``) must appear in
  ``docs/observability.md``, the single event/metric catalog.  This
  supersedes hand-maintained name lists: add a counter, and CI fails
  until the catalog row exists.

Known limitation (documented in docs/analysis.md): f-string event names
(``stage.{node.name}``, ``reshard.to_{want}``) are not literal and are
skipped; the catalog documents those families as ``stage.<node>`` /
``reshard.to_*`` and ``tools/trace_report.py --expect`` covers them
dynamically.
"""
from __future__ import annotations

import ast
import re

from tools.analyze.core import (Finding, Project, dotted_name,
                                literal_names, register)

EMIT_METHODS = {"inc", "observe", "set", "set_max", "span", "instant",
                "counter"}
NAME_PREFIXES = ("serve.", "dock.", "graph.")


def _rlconfig_fields(project: Project) -> list[tuple[str, int]]:
    mod = project.module("src/repro/configs/base.py")
    if mod is None:
        return []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef) and node.name == "RLConfig":
            return [(item.target.id, item.lineno) for item in node.body
                    if isinstance(item, ast.AnnAssign)
                    and isinstance(item.target, ast.Name)]
    return []


def _emitter_receiver(call: ast.Call) -> bool:
    """True when the call receiver looks like the telemetry layer — a
    tracer or metrics registry (or the conventional tr/m locals)."""
    if not isinstance(call.func, ast.Attribute):
        return False
    recv = dotted_name(call.func.value)
    if recv is None:
        return False
    last = recv.split(".")[-1]
    return ("tracer" in last or "metrics" in last or last in ("tr", "m"))


@register("drift", ("DRF001", "DRF002"),
          "RLConfig knobs reachable; emitted serve./dock. names cataloged")
def run(project: Project) -> list[Finding]:
    findings: list[Finding] = []

    reach_text = (project.read_text("src/repro/launch/train.py")
                  + project.read_text("README.md")
                  + project.glob_text("docs/*.md"))
    for field, lineno in _rlconfig_fields(project):
        if not re.search(rf"\b{re.escape(field)}\b", reach_text):
            findings.append(Finding(
                "src/repro/configs/base.py", lineno, "DRF001",
                f"RLConfig.{field} is not reachable from train.py nor "
                f"mentioned in README.md/docs/*.md — wire a CLI flag or "
                f"document the knob"))

    catalog = project.read_text("docs/observability.md")
    seen: set[str] = set()
    for mod in project.modules("src/repro"):
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and node.args
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in EMIT_METHODS
                    and _emitter_receiver(node)):
                continue
            for name in literal_names(node.args[0]):
                if not name.startswith(NAME_PREFIXES) or name in seen:
                    continue
                seen.add(name)
                if name not in catalog:
                    findings.append(Finding(
                        mod.rel, node.lineno, "DRF002",
                        f"emitted telemetry name `{name}` is missing from "
                        f"the docs/observability.md catalog"))
    return findings
