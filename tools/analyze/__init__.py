"""Contract-aware static analysis for this repo (``python -m tools.analyze``).

Six passes over the source tree, each encoding an invariant the test
suite can only probe dynamically:

* ``determinism``     — DET001/DET002: no unordered-set iteration or
  wall-clock/global-RNG in ``repro.serve``/``repro.core``.
* ``locks``           — LOCK001/LOCK002: ``# guarded-by:`` annotations
  verified lexically against ``with self.<lock>:`` blocks.
* ``tracer-overhead`` — TRC001: no tracer-argument allocation outside an
  ``.enabled`` guard in the hot-loop modules.
* ``kernel-shapes``   — KRN001..KRN004: Pallas grid/BlockSpec agreement,
  docstring assumptions enforced in code, VMEM budget respected.
* ``drift``           — DRF001/DRF002: RLConfig knobs reachable from
  train.py/docs; emitted ``serve.*``/``dock.*``/``graph.*`` names
  cataloged in docs/observability.md.
* ``faults``          — FLT001: injected fault-site names cataloged in
  docs/resilience.md.

See docs/analysis.md for the rule catalog and the baseline workflow.
Importing this package registers all passes.
"""
# registration imports: each pass module's @register call populates PASSES
from tools.analyze import (determinism, drift, faults, kernels,  # noqa: F401
                           locks, overhead)
from tools.analyze.core import (Finding, Project, apply_baseline,  # noqa: F401
                                load_baseline, run_passes)

__all__ = ["Finding", "Project", "apply_baseline", "load_baseline",
           "run_passes"]
