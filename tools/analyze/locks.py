"""Lock-discipline pass: `# guarded-by:` annotations, lexically verified.

The convention (documented in docs/analysis.md):

* An attribute assignment carrying a trailing ``# guarded-by: <lock>``
  comment declares that EVERY access of ``self.<attr>`` in the class must
  be lexically inside ``with self.<lock>:`` (dotted locks like
  ``swap._cond`` are supported).  Declarations usually live in
  ``__init__`` next to the lock itself.
* A method whose ``def`` line (or the line above it) carries
  ``# thread-confined: <why>`` is exempt — it runs only on a single
  thread by construction (the comment says which and why).
* A method carrying ``# requires-lock: <lock>`` asserts its CALLERS hold
  the lock; its body is checked as if the lock were held throughout.
* ``__init__`` is implicitly thread-confined (no concurrent aliases can
  exist while the object is being constructed).

Rules:

* **LOCK001** — access to a guarded attribute outside its lock (and not
  in a thread-confined / requires-lock method).
* **LOCK002** — a declared lock that no ``with self.<lock>:`` in the
  class ever acquires (dead or misspelled annotation).

The check is lexical, not interprocedural: a guarded attribute reached
through a local alias (``t = self.x`` hoisted out of the lock) or from
another object's method is invisible to it.  That is the right trade for
an annotation the reader can verify by eye — the annotation marks the
discipline, the pass keeps it honest.
"""
from __future__ import annotations

import ast
import re

from tools.analyze.core import Finding, Module, Project, register, \
    self_attr_path

GUARD_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][\w.]*)")
CONFINED_RE = re.compile(r"#\s*thread-confined\b")
REQUIRES_RE = re.compile(r"#\s*requires-lock:\s*([A-Za-z_][\w.]*)")


def _def_comment(mod: Module, fn: ast.FunctionDef, pattern: re.Pattern):
    """Match ``pattern`` on the ``def`` line or the line directly above
    (decorators push the def down; lineno is the ``def`` itself)."""
    for lineno in (fn.lineno, fn.lineno - 1):
        m = pattern.search(mod.line(lineno))
        if m:
            return m
    return None


def _guarded_attrs(mod: Module, cls: ast.ClassDef) -> dict[str, tuple]:
    """attr name -> (lock path, declaration line)."""
    out: dict[str, tuple] = {}
    for node in ast.walk(cls):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            m = GUARD_RE.search(mod.line(node.lineno))
            if not m:
                continue
            for tgt in targets:
                path = self_attr_path(tgt)
                if path and "." not in path:
                    out[path] = (m.group(1), node.lineno)
    return out


def _acquired_locks(cls: ast.ClassDef) -> set[str]:
    """Every ``self.<dotted>`` appearing as a with-item in the class."""
    locks: set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                path = self_attr_path(item.context_expr)
                if path:
                    locks.add(path)
    return locks


class _MethodChecker(ast.NodeVisitor):
    """Walk one method body tracking the lexically-held lock set."""

    def __init__(self, mod: Module, guarded: dict[str, tuple],
                 held: set[str]):
        self.mod = mod
        self.guarded = guarded
        self.held = set(held)
        self.findings: list[Finding] = []

    def visit_With(self, node: ast.With):
        added = []
        for item in node.items:
            path = self_attr_path(item.context_expr)
            if path:
                added.append(path)
            self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        self.held.update(added)
        for stmt in node.body:
            self.visit(stmt)
        self.held.difference_update(added)

    visit_AsyncWith = visit_With

    def visit_Attribute(self, node: ast.Attribute):
        path = self_attr_path(node)
        # `self.swap._cond` reports attr 'swap' at the self boundary — the
        # guarded name is always the FIRST component
        if path is not None:
            first = path.split(".")[0]
            info = self.guarded.get(first)
            if info is not None and info[0] not in self.held:
                self.findings.append(Finding(
                    self.mod.rel, node.lineno, "LOCK001",
                    f"`self.{first}` is declared `# guarded-by: {info[0]}` "
                    f"but is accessed outside `with self.{info[0]}:` "
                    f"(annotate the method `# thread-confined:`/"
                    f"`# requires-lock:` if this is by design)"))
            return   # a pure self-chain: prefixes are the same access
        self.generic_visit(node)


def _check_class(mod: Module, cls: ast.ClassDef) -> list[Finding]:
    guarded = _guarded_attrs(mod, cls)
    if not guarded:
        return []
    findings: list[Finding] = []

    acquired = _acquired_locks(cls)
    for attr, (lock, lineno) in sorted(guarded.items()):
        if lock not in acquired:
            findings.append(Finding(
                mod.rel, lineno, "LOCK002",
                f"`self.{attr}` declares `# guarded-by: {lock}` but no "
                f"`with self.{lock}:` exists in class {cls.name} — dead "
                f"or misspelled lock annotation"))

    for item in cls.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if item.name == "__init__":
            continue                      # implicitly thread-confined
        if _def_comment(mod, item, CONFINED_RE):
            continue
        held: set[str] = set()
        m = _def_comment(mod, item, REQUIRES_RE)
        if m:
            held.add(m.group(1))
        checker = _MethodChecker(mod, guarded, held)
        for stmt in item.body:
            checker.visit(stmt)
        findings.extend(checker.findings)
    return findings


@register("locks", ("LOCK001", "LOCK002"),
          "guarded-by annotations verified lexically against with-blocks")
def run(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules("src/repro"):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(_check_class(mod, node))
    return findings
