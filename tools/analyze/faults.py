"""Fault-site catalog pass: chaos hooks stay documented.

* **FLT001** — every literal fault-site name passed to a ``FaultPlan``
  check (``<...>.faults.check("site")`` — any receiver whose dotted name
  ends in ``faults``) must appear in ``docs/resilience.md``, the single
  fault-site catalog.  A site the catalog does not list cannot be targeted
  from ``--fault-plan`` by anyone who reads the docs, so the chaos surface
  silently shrinks — the same drift DRF002 guards against for telemetry
  names.

Known limitation (same as DRF002's): computed site names are not literal
and are skipped — ``"stage." + node.name`` (core/graph.py) is the one
such family, documented in the catalog as ``stage.<node>``.
"""
from __future__ import annotations

import ast

from tools.analyze.core import (Finding, Project, dotted_name,
                                literal_names, register)


def _faults_receiver(call: ast.Call) -> bool:
    """True for ``<recv>.check(...)`` where recv names a fault plan —
    ``self.faults``, ``plan.faults``, a bare ``faults`` local, ..."""
    if not (isinstance(call.func, ast.Attribute)
            and call.func.attr == "check"):
        return False
    recv = dotted_name(call.func.value)
    return recv is not None and recv.split(".")[-1] == "faults"


@register("faults", ("FLT001",),
          "injected fault-site names cataloged in docs/resilience.md")
def run(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    catalog = project.read_text("docs/resilience.md")
    seen: set[str] = set()
    for mod in project.modules("src/repro"):
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and node.args
                    and _faults_receiver(node)):
                continue
            for name in literal_names(node.args[0]):
                if name in seen:
                    continue
                seen.add(name)
                if name not in catalog:
                    findings.append(Finding(
                        mod.rel, node.lineno, "FLT001",
                        f"fault site `{name}` is missing from the "
                        f"docs/resilience.md catalog"))
    return findings
