"""CLI: ``python -m tools.analyze [--rule ID] [--baseline PATH]``.

Runs every registered pass (or the ones selected with ``--rule``, which
accepts a pass name or a rule-id prefix), subtracts the baseline, prints
one ``file:line: RULE message`` per unsuppressed finding, and exits
nonzero when any remain — the CI ``analysis`` job is exactly this
invocation.  ``--no-baseline`` shows everything; ``--list-rules`` prints
the registry.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.analyze.core import (PASSES, Project, apply_baseline,
                                load_baseline, run_passes)

REPO_ROOT = Path(__file__).resolve().parents[2]
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def _select_passes(rule: str | None) -> list[str] | None:
    if rule is None:
        return None
    if rule in PASSES:
        return [rule]
    matched = [name for name, p in PASSES.items()
               if any(r.startswith(rule) for r in p.rule_ids)]
    if not matched:
        known = sorted(r for p in PASSES.values() for r in p.rule_ids)
        sys.exit(f"unknown rule or pass {rule!r}; passes: "
                 f"{sorted(PASSES)}; rules: {known}")
    return matched


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="contract-aware static analysis (see docs/analysis.md)")
    ap.add_argument("--rule", default=None,
                    help="run only one pass (by name) or the passes owning "
                         "a rule-id prefix (e.g. LOCK, KRN003)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="suppression file (default: the shipped baseline)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report suppressed findings too")
    ap.add_argument("--root", default=str(REPO_ROOT),
                    help="tree to analyze (default: this repo)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in sorted(PASSES):
            p = PASSES[name]
            print(f"{name:16s} {', '.join(p.rule_ids):30s} {p.doc}")
        return 0

    project = Project(args.root)
    findings = run_passes(project, _select_passes(args.rule))

    entries = [] if args.no_baseline else load_baseline(args.baseline)
    kept, suppressed, stale = apply_baseline(findings, entries)

    # rule filter may narrow within a pass (e.g. KRN003 of kernel-shapes)
    if args.rule and args.rule not in PASSES:
        kept = [f for f in kept if f.rule_id.startswith(args.rule)]

    for f in kept:
        print(f.render())
    for e in stale:
        print(f"warning: stale baseline entry matched nothing: "
              f"{e['rule']} {e['file']} ({e['reason']})", file=sys.stderr)
    n_pass = len(_select_passes(args.rule) or PASSES)
    print(f"tools.analyze: {len(kept)} finding(s), {len(suppressed)} "
          f"baseline-suppressed, {n_pass} pass(es)", file=sys.stderr)
    return 1 if kept else 0


if __name__ == "__main__":
    sys.exit(main())
