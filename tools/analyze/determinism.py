"""Determinism pass: no unordered iteration, no wall-clock/global RNG.

Scope: ``src/repro/serve`` and ``src/repro/core`` — the modules behind the
greedy bit-identity contract (serving ≡ sync RolloutEngine) and the
deterministic executor trace.  Two rules:

* **DET001** — iteration over a ``set``/``frozenset`` value feeding an
  order-sensitive consumer (``for`` loop, list/generator comprehension).
  Python sets iterate in hash order, which varies with PYTHONHASHSEED and
  insertion history, so any control flow derived from such an iteration is
  run-to-run nondeterministic.  Wrapping in ``sorted()`` (or any
  order-free reducer: ``len``/``sum``/``min``/``max``/``any``/``all``/
  ``set``/``frozenset``) is the fix and is recognized.
* **DET002** — calls into wall-clock or process-global RNG state:
  ``time.time``/``time.time_ns`` (and other wall-clock ``time`` members),
  ``datetime.*``, module-level ``random.*``, ``numpy.random.*``.  The
  repo's clock is ``time.perf_counter[_ns]`` (monotonic, used only for
  timing, never control flow) and its randomness is ``jax.random`` with
  explicit keys — both allowed.

Set-typed values are recognized structurally: set literals/comprehensions,
``set(...)``/``frozenset(...)`` calls, set-operator expressions (``|``
``&`` ``-`` ``^`` of a set), and local names/``self`` attributes assigned
or annotated as sets within the enclosing scope.  This is intentionally
lexical — no type inference across calls — so it can miss aliased sets,
but it cannot false-positive on lists/dicts.
"""
from __future__ import annotations

import ast

from tools.analyze.core import (Finding, Module, Project, dotted_name,
                                parent_map, register)

SCOPE_DIRS = ("src/repro/serve", "src/repro/core")

# order-free consumers: iterating a set inside these is deterministic in
# effect (result does not depend on iteration order)
ORDER_FREE_CALLS = {"sorted", "set", "frozenset", "len", "sum", "min",
                    "max", "any", "all"}

TIME_ALLOWED = {"perf_counter", "perf_counter_ns", "monotonic",
                "monotonic_ns", "sleep"}

SET_OPS = (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)


def _is_set_annotation(node: ast.AST | None) -> bool:
    if node is None:
        return False
    text = ast.dump(node)
    return ("'set'" in text or "'frozenset'" in text or "'Set'" in text
            or "'FrozenSet'" in text)


class _SetVars(ast.NodeVisitor):
    """Collect names (and ``self.x`` paths) bound to set values in a scope.
    One flat pass — no flow sensitivity, last annotation wins."""

    def __init__(self):
        self.names: set[str] = set()

    def visit_Assign(self, node: ast.Assign):
        if is_set_expr(node.value, self.names):
            for tgt in node.targets:
                dn = dotted_name(tgt)
                if dn:
                    self.names.add(dn)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        dn = dotted_name(node.target)
        if dn and (_is_set_annotation(node.annotation)
                   or (node.value is not None
                       and is_set_expr(node.value, self.names))):
            self.names.add(dn)
        self.generic_visit(node)


def is_set_expr(node: ast.AST, set_vars: set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, SET_OPS):
        return (is_set_expr(node.left, set_vars)
                or is_set_expr(node.right, set_vars))
    dn = dotted_name(node)
    if dn is not None and dn in set_vars:
        return True
    # x.copy() / x.union(...) / x.difference(...) of a known set
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr in ("copy", "union", "intersection",
                                   "difference", "symmetric_difference"):
        return is_set_expr(node.func.value, set_vars)
    return False


def _order_free_context(node: ast.AST, parents: dict) -> bool:
    """True when a comprehension's result is consumed order-free — its
    immediate parent is a call to an order-insensitive reducer."""
    parent = parents.get(node)
    return (isinstance(parent, ast.Call)
            and isinstance(parent.func, ast.Name)
            and parent.func.id in ORDER_FREE_CALLS)


def _collect_imports(tree: ast.Module) -> dict[str, str]:
    """Local alias -> dotted origin ('np' -> 'numpy',
    'time' (from-import) -> 'time.time')."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _resolve_call(func: ast.AST, aliases: dict[str, str]) -> str | None:
    """Fully-qualified dotted name of a call target, alias-expanded."""
    dn = dotted_name(func)
    if dn is None:
        return None
    root, _, rest = dn.partition(".")
    origin = aliases.get(root)
    if origin is None:
        return dn
    return f"{origin}.{rest}" if rest else origin


def _banned_call(qual: str) -> str | None:
    if qual.startswith("time."):
        member = qual.split(".", 1)[1]
        if member not in TIME_ALLOWED:
            return f"wall-clock `{qual}`"
    if qual.startswith("datetime."):
        return f"wall-clock `{qual}`"
    if qual == "random" or qual.startswith("random."):
        return f"process-global RNG `{qual}`"
    if qual.startswith("numpy.random"):
        return f"process-global RNG `{qual}`"
    return None


def _check_module(mod: Module) -> list[Finding]:
    findings: list[Finding] = []
    parents = parent_map(mod.tree)
    aliases = _collect_imports(mod.tree)

    # scope -> set-typed names (module scope + each function scope)
    scopes = [mod.tree] + [n for n in ast.walk(mod.tree)
                           if isinstance(n, (ast.FunctionDef,
                                             ast.AsyncFunctionDef))]
    set_vars_by_scope: dict[ast.AST, set[str]] = {}
    for scope in scopes:
        sv = _SetVars()
        sv.visit(scope)
        set_vars_by_scope[scope] = sv.names

    def enclosing_sets(node: ast.AST) -> set[str]:
        names: set[str] = set()
        cur = node
        while cur is not None:
            names |= set_vars_by_scope.get(cur, set())
            cur = parents.get(cur)
        return names

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.For):
            if is_set_expr(node.iter, enclosing_sets(node)):
                findings.append(Finding(
                    mod.rel, node.lineno, "DET001",
                    "for-loop over a set iterates in hash order — sort it "
                    "(`for x in sorted(...)`) or use an ordered container"))
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp)):
            gen = node.generators[0]
            if is_set_expr(gen.iter, enclosing_sets(node)) \
                    and not _order_free_context(node, parents):
                findings.append(Finding(
                    mod.rel, node.lineno, "DET001",
                    "comprehension over a set feeds an order-sensitive "
                    "consumer — wrap the set in sorted() or restructure"))
        elif isinstance(node, ast.Call):
            qual = _resolve_call(node.func, aliases)
            if qual:
                why = _banned_call(qual)
                if why:
                    findings.append(Finding(
                        mod.rel, node.lineno, "DET002",
                        f"{why} in deterministic scope — use "
                        f"time.perf_counter for timing, jax.random with an "
                        f"explicit key for randomness"))
    return findings


@register("determinism", ("DET001", "DET002"),
          "unordered iteration / wall-clock / global RNG in serve+core")
def run(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules(*SCOPE_DIRS):
        findings.extend(_check_module(mod))
    return findings
