"""Shared machinery for the contract analyzer: Finding, Project, registry.

Every pass is a function ``pass_fn(project) -> list[Finding]`` registered
under a short name with the rule ids it may emit.  Passes operate on a
``Project`` — a lazily-parsed view of one source tree — so tests can run
any pass against a throwaway fixture tree with the same relative layout as
the repo (``src/repro/serve/...``) and get exactly the CI behavior.

The baseline file (``tools/analyze/baseline.json``) suppresses DELIBERATE
exceptions.  Entries match on ``rule`` + ``file`` + a ``contains``
substring of the message — never on line numbers, so unrelated churn in a
file cannot silently detach a suppression — and every entry must carry a
``reason``.  Stale entries (matching nothing) are reported so the baseline
shrinks when the code it excuses is fixed.
"""
from __future__ import annotations

import ast
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Iterable


@dataclass(frozen=True)
class Finding:
    """One rule violation, addressed by repo-relative file + 1-based line."""
    file: str
    line: int
    rule_id: str
    message: str

    def render(self) -> str:
        return f"{self.file}:{self.line}: {self.rule_id} {self.message}"


class Module:
    """One parsed source file: AST plus raw lines (for trailing comments,
    which the AST does not keep)."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(path))

    def line(self, lineno: int) -> str:
        """Source text of 1-based ``lineno`` ('' when out of range)."""
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


class Project:
    """A source tree rooted at ``root``; parses files on demand."""

    def __init__(self, root):
        self.root = Path(root).resolve()
        self._modules: dict[str, Module | None] = {}

    def rel(self, path: Path) -> str:
        return path.resolve().relative_to(self.root).as_posix()

    def module(self, rel: str) -> Module | None:
        """Parsed module for a repo-relative path, or None if absent."""
        if rel not in self._modules:
            path = self.root / rel
            self._modules[rel] = (Module(path, rel) if path.is_file()
                                  else None)
        return self._modules[rel]

    def modules(self, *rel_dirs: str) -> list[Module]:
        """All ``.py`` modules under the given repo-relative dirs, sorted
        by path (deterministic pass order)."""
        out: list[Module] = []
        for rel_dir in rel_dirs:
            base = self.root / rel_dir
            if not base.is_dir():
                continue
            for path in sorted(base.rglob("*.py")):
                mod = self.module(self.rel(path))
                if mod is not None:
                    out.append(mod)
        return out

    def read_text(self, rel: str) -> str:
        path = self.root / rel
        return path.read_text() if path.is_file() else ""

    def glob_text(self, pattern: str) -> str:
        """Concatenated text of every file matching a repo-relative glob."""
        return "\n".join(p.read_text()
                         for p in sorted(self.root.glob(pattern))
                         if p.is_file())


# -- registry ---------------------------------------------------------------

@dataclass(frozen=True)
class Pass:
    name: str
    rule_ids: tuple
    doc: str
    fn: Callable


PASSES: dict[str, Pass] = {}


def register(name: str, rule_ids: Iterable[str], doc: str):
    """Decorator: register a pass under ``name`` with its rule ids."""
    def wrap(fn):
        PASSES[name] = Pass(name, tuple(rule_ids), doc, fn)
        return fn
    return wrap


def rule_owner(rule_id: str) -> str | None:
    for p in PASSES.values():
        if rule_id in p.rule_ids:
            return p.name
    return None


def run_passes(project: Project, names: Iterable[str] | None = None
               ) -> list[Finding]:
    """Run the named passes (default: all) and return sorted findings."""
    names = list(names) if names is not None else sorted(PASSES)
    findings: list[Finding] = []
    for name in names:
        findings.extend(PASSES[name].fn(project))
    return sorted(findings, key=lambda f: (f.file, f.line, f.rule_id,
                                           f.message))


# -- baseline ---------------------------------------------------------------

def load_baseline(path) -> list[dict]:
    """Baseline entries: {"rule", "file", "contains", "reason"}."""
    entries = json.loads(Path(path).read_text())
    for i, e in enumerate(entries):
        missing = {"rule", "file", "contains", "reason"} - set(e)
        if missing:
            raise ValueError(f"baseline entry {i} missing keys "
                             f"{sorted(missing)}: {e}")
    return entries


def apply_baseline(findings: list[Finding], entries: list[dict]
                   ) -> tuple[list[Finding], list[Finding], list[dict]]:
    """Split into (unsuppressed, suppressed, stale_entries)."""
    used = [False] * len(entries)
    kept, suppressed = [], []
    for f in findings:
        hit = None
        for i, e in enumerate(entries):
            if (e["rule"] == f.rule_id and e["file"] == f.file
                    and e["contains"] in f.message):
                hit = i
                break
        if hit is None:
            kept.append(f)
        else:
            used[hit] = True
            suppressed.append(f)
    stale = [e for i, e in enumerate(entries) if not used[i]]
    return kept, suppressed, stale


# -- small AST helpers shared by passes -------------------------------------

def parent_map(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def dotted_name(node: ast.AST) -> str | None:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def self_attr_path(node: ast.AST) -> str | None:
    """'x' for ``self.x``, 'x.y' for ``self.x.y``, else None."""
    dn = dotted_name(node)
    if dn and dn.startswith("self."):
        return dn[len("self."):]
    return None


def literal_names(arg: ast.AST) -> list[str]:
    """String constants a name argument can evaluate to (handles the
    ``a if cond else b`` split-name idiom).  Non-literal names (f-strings,
    concatenations) yield [] — callers document those families separately."""
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return [arg.value]
    if isinstance(arg, ast.IfExp):
        return literal_names(arg.body) + literal_names(arg.orelse)
    return []
