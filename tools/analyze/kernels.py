"""Pallas kernel-shape pass: grid/BlockSpec consistency, stated
assumptions enforced, VMEM budget respected.

Scope: every module under ``src/repro`` containing a ``pallas_call``
(the kernels package plus the paged-cache gather kernel).  Three checks
per call site, one annotation convention:

* **KRN001** — BlockSpec/grid consistency.  Each ``BlockSpec`` index-map
  lambda must take ``len(grid) + num_scalar_prefetch`` arguments, and
  when its body is a tuple, return one coordinate per block-shape
  dimension.  (Wrong arity fails loudly at trace time; this catches it
  at review time, and in both jit-cached and cold paths.)
* **KRN002** — a kernel wrapper whose docstring states a divisibility /
  power-of-two / alignment assumption must enforce it in code: an
  ``assert``/``raise``, a ``while x % b: b //= 2`` block-shrink loop, or
  a call into a ``pad``-named helper.  Stated-but-unenforced assumptions
  are exactly how interpret-mode-green kernels die on real shapes.
* **KRN003 / KRN004** — the summed upper-bound VMEM footprint of one
  program's blocks (in/out specs + scratch, f32 accounting) must fit
  ``VMEM_BUDGET_BYTES`` (16 MiB/core, the TPU guide number).  Dimension
  upper bounds resolve from literals, parameter defaults, ``min(...)``
  shrink patterns, and the module's ``VMEM_BOUNDS = {dim: bound}``
  declaration — a dimension none of those bound is itself a finding
  (KRN004), so every kernel documents the deployment envelope its tiling
  was sized for.

All resolution is intraprocedural and conservative: bounds are upper
bounds, and ``min(a, b)`` takes the smallest resolvable operand.
"""
from __future__ import annotations

import ast
import re

from tools.analyze.core import Finding, Module, Project, dotted_name, \
    register

VMEM_BUDGET_BYTES = 16 * 1024 * 1024      # per-core VMEM (TPU v4/v5e class)
DTYPE_BYTES = 4                           # f32 accounting (upper bound)

ASSUMPTION_RE = re.compile(
    r"multiple of|divisible|divides|power of two|power-of-two|pow2|aligned|"
    r"% == 0|must be even", re.IGNORECASE)


# -- bound resolution -------------------------------------------------------

class _Env:
    """Upper bounds for names in one function: assignments, parameter
    defaults, and the module-level VMEM_BOUNDS dict."""

    def __init__(self, fn: ast.FunctionDef, module_bounds: dict[str, int]):
        self.assigns: dict[str, ast.AST] = {}
        self.defaults: dict[str, int] = {}
        self.module_bounds = module_bounds
        args = fn.args
        pos = args.posonlyargs + args.args
        for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
            if isinstance(d, ast.Constant) and isinstance(d.value, int):
                self.defaults[a.arg] = d.value
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if isinstance(d, ast.Constant) and isinstance(d.value, int):
                self.defaults[a.arg] = d.value
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                self.assigns[node.targets[0].id] = node.value

    def bound(self, node: ast.AST, stack: frozenset = frozenset()
              ) -> int | None:
        if isinstance(node, ast.Constant):
            return node.value if isinstance(node.value, int) else None
        if isinstance(node, ast.Name):
            name = node.id
            if name not in stack and name in self.assigns:
                b = self.bound(self.assigns[name], stack | {name})
                if b is not None:
                    return b
            if name in self.defaults:
                return self.defaults[name]
            return self.module_bounds.get(name)
        if isinstance(node, ast.BinOp):
            left = self.bound(node.left, stack)
            right = self.bound(node.right, stack)
            if left is None or right is None:
                return None
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.FloorDiv) and right:
                return left // right
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left            # upper bound: ignore the subtrahend
            return None
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            operands = [self.bound(a, stack) for a in node.args]
            known = [b for b in operands if b is not None]
            if node.func.id == "min" and known:
                return min(known)      # sound: true min <= any operand
            if node.func.id == "max" and len(known) == len(operands) \
                    and known:
                return max(known)
        return None


def _module_bounds(tree: ast.Module) -> dict[str, int]:
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == "VMEM_BOUNDS" \
                and isinstance(node.value, ast.Dict):
            out = {}
            for k, v in zip(node.value.keys, node.value.values):
                if isinstance(k, ast.Constant) and isinstance(v, ast.Constant):
                    out[k.value] = v.value
            return out
    return {}


# -- call-site model --------------------------------------------------------

def _kw(call: ast.Call, name: str) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _resolve_name(node: ast.AST, env: _Env) -> ast.AST:
    """Follow one level of local Name -> assignment (spec aliases)."""
    if isinstance(node, ast.Name) and node.id in env.assigns:
        return env.assigns[node.id]
    return node


def _is_call_to(node: ast.AST, name: str) -> bool:
    return (isinstance(node, ast.Call)
            and (dotted_name(node.func) or "").split(".")[-1] == name)


def _block_specs(seq: ast.AST, env: _Env) -> list[ast.Call]:
    seq = _resolve_name(seq, env)
    items = seq.elts if isinstance(seq, (ast.List, ast.Tuple)) else [seq]
    out = []
    for item in items:
        item = _resolve_name(item, env)
        if _is_call_to(item, "BlockSpec"):
            out.append(item)
    return out


def _grid_info(call: ast.Call, env: _Env):
    """(ngrid, nprefetch, in_specs, out_specs, scratch) or None."""
    grid = _kw(call, "grid")
    if grid is not None:
        grid = _resolve_name(grid, env)
        if not isinstance(grid, ast.Tuple):
            return None
        return (len(grid.elts), 0, _kw(call, "in_specs"),
                _kw(call, "out_specs"), None)
    spec = _kw(call, "grid_spec")
    if spec is None:
        return None
    spec = _resolve_name(spec, env)
    if not _is_call_to(spec, "PrefetchScalarGridSpec"):
        return None
    g = _resolve_name(_kw(spec, "grid") or ast.Constant(None), env)
    if not isinstance(g, ast.Tuple):
        return None
    npre = _kw(spec, "num_scalar_prefetch")
    npre = npre.value if isinstance(npre, ast.Constant) else 0
    return (len(g.elts), npre, _kw(spec, "in_specs"),
            _kw(spec, "out_specs"), _kw(spec, "scratch_shapes"))


def _check_spec(mod: Module, spec: ast.Call, ngrid: int, npre: int,
                env: _Env) -> tuple[list[Finding], int | None]:
    """KRN001 on one BlockSpec; returns (findings, byte upper bound)."""
    findings: list[Finding] = []
    shape = spec.args[0] if spec.args else _kw(spec, "block_shape")
    index_map = (spec.args[1] if len(spec.args) > 1
                 else _kw(spec, "index_map"))
    dims = shape.elts if isinstance(shape, ast.Tuple) else None

    if isinstance(index_map, ast.Lambda):
        want = ngrid + npre
        got = len(index_map.args.args)
        if got != want:
            findings.append(Finding(
                mod.rel, index_map.lineno, "KRN001",
                f"BlockSpec index_map takes {got} args but grid rank "
                f"{ngrid} + {npre} scalar-prefetch operands requires "
                f"{want}"))
        if dims is not None and isinstance(index_map.body, ast.Tuple) \
                and len(index_map.body.elts) != len(dims):
            findings.append(Finding(
                mod.rel, index_map.lineno, "KRN001",
                f"BlockSpec index_map returns "
                f"{len(index_map.body.elts)} coordinates for a "
                f"{len(dims)}-dimensional block shape"))

    if dims is None:
        return findings, None
    total = DTYPE_BYTES
    for dim in dims:
        b = env.bound(dim)
        if b is None:
            findings.append(Finding(
                mod.rel, dim.lineno, "KRN004",
                f"cannot bound block dimension "
                f"`{ast.unparse(dim)}` — add it to this module's "
                f"VMEM_BOUNDS so the VMEM budget check covers this "
                f"kernel"))
            return findings, None
        total *= b
    return findings, total


def _check_call(mod: Module, fn: ast.FunctionDef, call: ast.Call,
                env: _Env) -> list[Finding]:
    findings: list[Finding] = []
    info = _grid_info(call, env)
    if info is None:
        findings.append(Finding(
            mod.rel, call.lineno, "KRN004",
            "pallas_call grid is not statically resolvable (literal tuple "
            "or local PrefetchScalarGridSpec) — the shape checks cannot "
            "run"))
        return findings
    ngrid, npre, in_specs, out_specs, scratch = info
    total = 0
    bounded = True
    for seq in (in_specs, out_specs):
        if seq is None:
            continue
        for spec in _block_specs(seq, env):
            fs, nbytes = _check_spec(mod, spec, ngrid, npre, env)
            findings.extend(fs)
            if nbytes is None:
                bounded = False
            else:
                total += nbytes
    if scratch is not None:
        scratch = _resolve_name(scratch, env)
        items = scratch.elts if isinstance(scratch, (ast.List, ast.Tuple)) \
            else []
        for item in items:
            if _is_call_to(item, "VMEM") and item.args \
                    and isinstance(item.args[0], ast.Tuple):
                nbytes = DTYPE_BYTES
                for dim in item.args[0].elts:
                    b = env.bound(dim)
                    if b is None:
                        bounded = False
                        findings.append(Finding(
                            mod.rel, dim.lineno, "KRN004",
                            f"cannot bound scratch dimension "
                            f"`{ast.unparse(dim)}` — add it to "
                            f"VMEM_BOUNDS"))
                        break
                    nbytes *= b
                else:
                    total += nbytes
    if bounded and total > VMEM_BUDGET_BYTES:
        findings.append(Finding(
            mod.rel, call.lineno, "KRN003",
            f"per-program VMEM upper bound "
            f"{total / 2**20:.1f} MiB exceeds the "
            f"{VMEM_BUDGET_BYTES / 2**20:.0f} MiB budget — shrink the "
            f"default block sizes or tighten VMEM_BOUNDS"))
    return findings


def _has_enforcement(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assert, ast.Raise)):
            return True
        if isinstance(node, ast.While) and any(
                isinstance(s, ast.BinOp) and isinstance(s.op, ast.Mod)
                for s in ast.walk(node.test)):
            return True                  # `while x % b: b //= 2` shrink
        if isinstance(node, ast.Call):
            name = (dotted_name(node.func) or "").split(".")[-1]
            if "pad" in name:
                return True
    return False


def _check_fn(mod: Module, fn: ast.FunctionDef, seen: set
              ) -> list[Finding]:
    calls = [n for n in ast.walk(fn)
             if isinstance(n, ast.Call)
             and (dotted_name(n.func) or "").split(".")[-1] == "pallas_call"
             and id(n) not in seen]
    if not calls:
        return []
    seen.update(id(c) for c in calls)
    findings: list[Finding] = []
    doc = ast.get_docstring(fn) or ""
    if ASSUMPTION_RE.search(doc) and not _has_enforcement(fn):
        findings.append(Finding(
            mod.rel, fn.lineno, "KRN002",
            f"`{fn.name}` docstring states a divisibility/alignment "
            f"assumption but the body has no assert, raise, block-shrink "
            f"loop, or pad call enforcing it"))
    env = _Env(fn, _module_bounds(mod.tree))
    for call in calls:
        findings.extend(_check_call(mod, fn, call, env))
    return findings


@register("kernel-shapes", ("KRN001", "KRN002", "KRN003", "KRN004"),
          "pallas grid/BlockSpec consistency, enforced assumptions, "
          "VMEM budget")
def run(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules("src/repro"):
        if "pallas_call" not in mod.source:
            continue
        seen: set = set()             # ast.walk is outer-first: the wrapper
        for node in ast.walk(mod.tree):  # claims its calls before any
            if isinstance(node, ast.FunctionDef):   # nested def re-walks them
                findings.extend(_check_fn(mod, node, seen))
    return findings
