"""Tracer-overhead pass: disabled tracing must allocate nothing.

The repo's contract (docs/observability.md "Overhead contract"): a
disabled tracer costs one predicate per instrumented site — ``span()``
returns a singleton, ``instant``/``counter`` early-return, and nothing is
appended or allocated.  The call itself honors that, but ARGUMENT
construction happens before the call: ``tr.instant("x", args={...})``
builds the dict even when disabled.  In the hot-loop modules this pass
therefore forbids any allocating argument expression (dict/list/tuple/
f-string/comprehension/nested call/arithmetic) at a tracer emission site
unless the site is lexically under an ``enabled`` guard.

Recognized guards:

* ``if <...>.enabled:`` (including ``tr is not None and tr.enabled``) —
  the body is guarded; an ``else:`` branch is not.
* ``if not <...>.enabled: return ...`` — every statement after it in the
  same block is guarded (the engine.step idiom).
* ``X if <...>.enabled else NULL_SPAN`` — the true branch is guarded.

**TRC001** — allocating tracer-call arguments outside an enabled guard.

Emission sites are calls to ``.span``/``.instant``/``.counter`` on a
receiver that names a tracer (``self.tracer``, ``tr``, ``tracer``).
Constant-only calls (``tr.instant("serve.x")``) pass unguarded — they
allocate nothing, matching the early-return contract.
"""
from __future__ import annotations

import ast

from tools.analyze.core import Finding, Module, Project, dotted_name, \
    register

HOT_MODULES = (
    "src/repro/serve/engine.py",
    "src/repro/serve/scheduler.py",
    "src/repro/serve/host_tier.py",
    "src/repro/core/graph.py",
    "src/repro/core/transfer_dock.py",
)

EMIT_METHODS = {"span", "instant", "counter"}

ALLOCATING = (ast.Dict, ast.List, ast.Set, ast.Tuple, ast.ListComp,
              ast.SetComp, ast.DictComp, ast.GeneratorExp, ast.JoinedStr,
              ast.Call, ast.BinOp, ast.NamedExpr)


def _is_tracer_call(node: ast.Call) -> bool:
    if not (isinstance(node.func, ast.Attribute)
            and node.func.attr in EMIT_METHODS):
        return False
    recv = dotted_name(node.func.value)
    if recv is None:
        return False
    last = recv.split(".")[-1]
    return "tracer" in last or last == "tr"


def _allocating_arg(node: ast.Call) -> ast.AST | None:
    for arg in list(node.args) + [kw.value for kw in node.keywords]:
        for sub in ast.walk(arg):
            if isinstance(sub, ALLOCATING):
                return sub
    return None


def _mentions_enabled(node: ast.AST) -> bool:
    return any(isinstance(sub, ast.Attribute) and sub.attr == "enabled"
               for sub in ast.walk(node))


def _test_polarity(test: ast.AST) -> str | None:
    """'pos' for `...enabled...`, 'neg' for `not ...enabled...`."""
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return "neg" if _mentions_enabled(test.operand) else None
    return "pos" if _mentions_enabled(test) else None


def _terminates(body: list[ast.stmt]) -> bool:
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


class _Checker:
    def __init__(self, mod: Module):
        self.mod = mod
        self.findings: list[Finding] = []

    # -- expressions --------------------------------------------------------
    def expr(self, node: ast.AST, guarded: bool) -> None:
        if isinstance(node, ast.IfExp):
            pol = _test_polarity(node.test)
            self.expr(node.test, guarded)
            self.expr(node.body, guarded or pol == "pos")
            self.expr(node.orelse, guarded or pol == "neg")
            return
        if isinstance(node, ast.Call) and _is_tracer_call(node):
            if not guarded:
                alloc = _allocating_arg(node)
                if alloc is not None:
                    self.findings.append(Finding(
                        self.mod.rel, node.lineno, "TRC001",
                        f"tracer .{node.func.attr}() argument builds a "
                        f"{type(alloc).__name__} outside an `.enabled` "
                        f"guard — a disabled tracer must allocate nothing "
                        f"(hoist under `if tr.enabled:` or use the "
                        f"early-return / NULL_SPAN idiom)"))
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # a nested callable runs later, possibly outside the guard
            inner = node.body if isinstance(node.body, list) else [node.body]
            if isinstance(node, ast.Lambda):
                self.expr(node.body, False)
            else:
                self.stmts(inner, False)
            return
        for child in ast.iter_child_nodes(node):
            self.expr(child, guarded)

    # -- statements ---------------------------------------------------------
    def stmts(self, body: list[ast.stmt], guarded: bool) -> None:
        after = guarded
        for stmt in body:
            if isinstance(stmt, ast.If):
                pol = _test_polarity(stmt.test)
                self.expr(stmt.test, after)
                self.stmts(stmt.body, after or pol == "pos")
                self.stmts(stmt.orelse, after or pol == "neg")
                if pol == "neg" and _terminates(stmt.body):
                    after = True          # `if not enabled: return` idiom
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.stmts(stmt.body, False)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self.expr(stmt.iter, after)
                self.stmts(stmt.body, after)
                self.stmts(stmt.orelse, after)
            elif isinstance(stmt, ast.While):
                self.expr(stmt.test, after)
                self.stmts(stmt.body, after)
                self.stmts(stmt.orelse, after)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self.expr(item.context_expr, after)
                self.stmts(stmt.body, after)
            elif isinstance(stmt, ast.Try):
                self.stmts(stmt.body, after)
                for handler in stmt.handlers:
                    self.stmts(handler.body, after)
                self.stmts(stmt.orelse, after)
                self.stmts(stmt.finalbody, after)
            elif isinstance(stmt, ast.ClassDef):
                self.stmts(stmt.body, False)
            else:
                self.expr(stmt, after)


@register("tracer-overhead", ("TRC001",),
          "no tracer-argument allocation outside enabled guards (hot loop)")
def run(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for rel in HOT_MODULES:
        mod = project.module(rel)
        if mod is None:
            continue
        checker = _Checker(mod)
        checker.stmts(mod.tree.body, False)
        findings.extend(checker.findings)
    return findings
